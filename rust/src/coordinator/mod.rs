//! Serving coordinator (L3): persistent three-party session + request
//! router + dynamic batcher + metrics, in the style of a vLLM router.
//!
//! A `Service` pins the three party threads for the lifetime of a model:
//! the model is secret-shared once, PJRT executables are warmed up once,
//! and every subsequent batch pays only the online protocol cost.  The
//! `Coordinator` in front owns the request queue and forms batches by
//! size/deadline -- batching in 3PC amortizes *rounds*, which is the
//! dominant WAN cost (the protocols are batched across samples inside the
//! engine, so a batch of 8 pays the same round count as a batch of 1).
//!
//! **Offline/online split.**  Each party thread spawns a background tuple
//! producer that mints MSB correlated material over the tagged
//! per-model offline transport lane into a watermark-managed
//! `offline::TupleBank`.  `Service::start` pre-fills every bank to the
//! high watermark before serving; the refill pump (`top_up_to`, driven by
//! the batcher's `BatchPolicy::prefetch` knob) broadcasts chunk-sized
//! refill jobs whenever deterministic headroom drops below the low
//! watermark.  Refill and infer jobs share one broadcast lock, so all
//! three parties observe the identical command order and agree on every
//! pooled-vs-fallback decision -- with a warm bank, a request performs
//! *zero* synchronous mints on its critical path (asserted by
//! `PreprocMetrics::underflow_calls == 0`).
//!
//! **Multi-model serving.**  A [`ModelRegistry`] hosts N `Service`s over
//! *one* process's three links: every model gets a channel-id slot
//! (`ChanId::online(slot)` / `ChanId::offline(slot)`), its own
//! model-scoped PRF seed domain (`engine::session::model_seed`, so no
//! two lanes ever share counters), its own auto-sized `TupleBank`, and
//! its own producer lane in the background minting pool.  Lanes demux
//! per frame at the transport layer, so interleaved batches for
//! different models compute exactly what their single-model sessions
//! would -- bit-identically (asserted by `rust/tests/multimodel.rs`).
//! See DESIGN.md §Multi-model multiplexing.
//!
//! **Registry lifecycle.**  Registry slots carry a typed state machine
//! ([`SlotState`]: `Starting -> Serving -> Draining -> Quarantined ->
//! Serving`).  A desynchronized slot is [`ModelRegistry::quarantine`]d:
//! its lanes are retired at the transport (waking any party thread
//! blocked mid-protocol with `WireError::Closed`), its threads joined,
//! its `TupleBank`s drained and dropped -- the other models sharing the
//! links never notice.  [`ModelRegistry::respawn`] restarts the slot on
//! the *same* `ChanId` lanes under a fresh seed epoch
//! (`engine::session::epoch_seed`).  [`ModelRegistry::add_model`] /
//! [`ModelRegistry::remove_model`] hot-swap models on a live registry:
//! removal quiesces (queued batches finish), retires the lanes (purging
//! their parked frames at the demux), and returns the slot id to a free
//! list that the next add reuses lowest-first.  Per-slot
//! `metrics::LifecycleCounters` record the history.  Pinned by
//! `rust/tests/lifecycle.rs`.

pub mod batcher;

pub use batcher::{Batcher, BatcherPolicy, PlaneConfig, RequestPlane,
                  ShardRouter, ShedReason};

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::engine::session::{epoch_seed, model_seed, SessionConfig};
use crate::engine::{infer_batch_pooled, msb_demand_for, share_model,
                    SharedModel};
use crate::metrics::{Histogram, LifecycleCounters, ModelRollup,
                     PreprocMetrics, Throughput};
use crate::nn::Model;
use crate::offline::{offline_seeds, run_producer, BankConfig, TupleBank,
                     TupleSource};
use crate::prf::PartySeeds;
use crate::protocols::Ctx;
use crate::ring::Tensor;
use crate::runtime::make_backend;
use crate::transport::{local_trio, ChanControl, ChanId, Comm, Stats};

/// Acquire coordinator bookkeeping locks, absorbing poisoning.  Every
/// guarded section here mutates scheduler/registry bookkeeping in
/// single steps (sends, counter bumps, entry pushes), so a panicking
/// holder never leaves the state torn -- recovering the guard keeps the
/// serving and lifecycle paths alive and *typed* (dead party threads
/// still surface through the existing send/lookup error paths), instead
/// of cascading one thread's panic into every request that follows.
/// Pinned by the `poisoned_*` tests below.
fn recover<T>(r: Result<MutexGuard<'_, T>, PoisonError<MutexGuard<'_, T>>>)
              -> MutexGuard<'_, T> {
    r.unwrap_or_else(PoisonError::into_inner)
}

enum Job {
    Infer {
        inputs: Vec<Tensor>,
        batch: usize,
        /// The request's trace id (minted by `Service::infer`); party
        /// threads park it in the thread-local the transport reads to
        /// attribute flight spans.
        trace: u64,
        /// Request-span label override (the batcher's tenant+shard
        /// attribution, `trace::request_label`); `None` closes the
        /// span under the model name as always.  Broadcast with the
        /// job, so all parties label identically.
        label: Option<String>,
    },
    /// Mint `n` more tuple elements in the background (forwarded to the
    /// party's producer thread; the bank is credited in broadcast order).
    Refill(usize),
    /// Retune the bank watermarks (adaptive sizing from the batcher's
    /// observed dispatch demand).  A broadcast job on purpose:
    /// `try_reserve` reads `chunk`/`capacity`, so all three parties
    /// must apply the resize at the identical point of the job order
    /// or their pooled-vs-fallback decisions could diverge.
    Retune { low: usize, high: usize, chunk: usize },
    Shutdown,
    /// Fault injection (tests, ops drills): the party thread returns
    /// immediately, skipping the graceful drain -- exactly the shape of
    /// a crashed thread.
    Die,
}

/// Broadcast state: the three job senders plus the pump's dispatch
/// accounting.  One lock for both, so every party sees refill and infer
/// jobs in the same order (the determinism the bank's credit accounting
/// relies on).
struct Sched {
    txs: Vec<Sender<Job>>,
    /// Elements promised by dispatched refill jobs.
    dispatched: usize,
}

/// A persistent three-party inference service for one model: pinned
/// party threads, a shared secret-shared model, per-party `TupleBank`s
/// kept warm by background producers, and a broadcast job queue whose
/// order every party observes identically (the determinism the bank's
/// credit accounting relies on).
///
/// A service either owns its own links (`Service::start`) or shares one
/// process's links with other models (`Service::start_on`, used by
/// [`ModelRegistry`]): its online protocol traffic runs on
/// `ChanId::online(slot)`, its producers on `ChanId::offline(slot)`,
/// and its PRF streams live in the model-scoped seed domain
/// `model_seed(session_seed, slot)`.
pub struct Service {
    sched: Mutex<Sched>,
    /// Mutex so concurrent holders (registry `Arc<Service>`) serialize
    /// batches exactly like the single-owner path always has.
    logits_rx: Mutex<Receiver<Result<Vec<Vec<i32>>>>>,
    /// Party thread handles until joined; `joined` caches the outcome
    /// (stats plus any drain failure) so shutdown/abort are idempotent
    /// -- a retried drain re-reports the same panic instead of
    /// upgrading it to a silent success.
    handles: Mutex<Vec<JoinHandle<Stats>>>,
    joined: Mutex<Option<([Stats; 3], Option<String>)>>,
    cancelled: AtomicBool,
    /// Per-party weak lifecycle levers on the links: retire this
    /// service's lanes without keeping the links alive (a dropped trio
    /// already surfaces `Closed` on its own).
    controls: Vec<ChanControl>,
    banks: Vec<Arc<TupleBank>>,
    bank_cfg: BankConfig,
    preprocess: bool,
    /// The binary-domain lowering when `opts.fuse` is on (public model
    /// structure, computed once at start; start fails on a model the
    /// planner rejects).  Tuple demand and the per-batch walk follow it.
    plan: Option<Arc<crate::engine::fusion::FusedPlan>>,
    /// Per-party trace sinks.  Installed on (or adopted from) the link
    /// cores at start -- registry slots sharing one trio share one sink
    /// per party, so flight-byte reconciliation spans every lane.
    sinks: Vec<Arc<crate::trace::TraceSink>>,
    /// Request-latency histogram (admin `stats`; fed by
    /// `Service::infer` on every successful batch).
    latency: Mutex<Histogram>,
    model: Arc<Model>,
    /// The channel-id model slot this service's lanes are bound to.
    pub slot: u8,
    /// The seed epoch this service runs (bumped per quarantine/respawn).
    pub epoch: u32,
    pub model_name: String,
    pub setup_time: Duration,
}

impl Service {
    /// Spin up the party threads over fresh in-process links, share the
    /// model, warm the PJRT caches, and pre-fill the tuple banks to the
    /// high watermark.
    pub fn start(model: Arc<Model>, cfg: SessionConfig) -> Result<Service> {
        Service::start_at(model, cfg, 0)
    }

    /// `start` pinned to channel-id model slot `slot` (fresh links).
    /// The single-model reference arm for multi-model tests: a service
    /// started at slot s standalone runs the identical seed domain and
    /// lane ids as slot s of a registry, so logits are bit-comparable.
    pub fn start_at(model: Arc<Model>, cfg: SessionConfig, slot: u8)
                    -> Result<Service> {
        Service::start_at_epoch(model, cfg, slot, 0)
    }

    /// `start_at` on an explicit seed epoch: the reference arm for
    /// respawned registry slots (a standalone service at the same slot
    /// and epoch is bit-comparable to the respawned one).
    pub fn start_at_epoch(model: Arc<Model>, cfg: SessionConfig, slot: u8,
                          epoch: u32) -> Result<Service> {
        let comms = local_trio(cfg.net);
        for c in &comms {
            c.set_parked_cap(cfg.max_parked_bytes);
        }
        Service::start_on_epoch(model, cfg, comms, slot, epoch)
    }

    /// Spin up this model's party threads over *externally provided*
    /// links -- the multi-model entry point.  `comms` are the three
    /// parties' handles of one shared link trio (any lane binding); the
    /// service derives -- and thereby registers, before any of its
    /// threads spawn -- its own `ChanId::online(slot)` /
    /// `ChanId::offline(slot)` lane pair, so its frames never
    /// interleave with another model's.  All PRF streams (online and
    /// producer) are drawn from the model-scoped seed domain
    /// `model_seed(cfg.session_seed, slot)`.
    pub fn start_on(model: Arc<Model>, cfg: SessionConfig,
                    comms: [Comm; 3], slot: u8) -> Result<Service> {
        Service::start_on_epoch(model, cfg, comms, slot, 0)
    }

    /// `start_on` on an explicit seed epoch (see
    /// `engine::session::epoch_seed`): the registry's respawn path --
    /// same `ChanId` lanes, fresh PRF domains, so the new service can
    /// never resume the quarantined epoch's correlated-randomness
    /// streams.
    pub fn start_on_epoch(model: Arc<Model>, cfg: SessionConfig,
                          comms: [Comm; 3], slot: u8, epoch: u32)
                          -> Result<Service> {
        // fused plans are public structure shared by all parties; a
        // model the planner rejects fails start with the typed reason
        // before any thread or lane exists
        let plan = if cfg.opts.fuse {
            Some(Arc::new(crate::engine::fusion::plan_fused(&model)?))
        } else {
            None
        };
        let bank_cfg = cfg.bank.unwrap_or_else(|| {
            let demand = match &plan {
                // fused demand is strictly no larger: folded signs and
                // OR-pools draw no tuples
                Some(p) => p.msb_demand(cfg.max_batch.max(1)),
                None => msb_demand_for(&model, cfg.max_batch.max(1)),
            };
            BankConfig::auto(demand)
        });
        bank_cfg.validate().map_err(|e| anyhow!("bank config: {e}"))?;
        let seed = epoch_seed(model_seed(cfg.session_seed, slot), epoch);
        // derive (= register) the lanes on every party BEFORE spawning
        // anything: a peer's first frame for this slot must find the id
        // registered, or the demux would reject it as malformed.  The
        // offline lane is derived only when producers will actually
        // read it -- registering a never-read id would hand a malicious
        // peer an unbounded parking queue instead of a Malformed error.
        let lanes: Vec<(Comm, Option<Comm>)> = comms.into_iter().map(|c| {
            let on = c.channel(ChanId::online(slot));
            let off = cfg.opts.preprocess
                .then(|| on.channel(ChanId::offline(slot)));
            (on, off)
        }).collect();
        // weak lifecycle levers (cancel/quarantine); weak so a retired
        // standalone service still drops its links (peers see Closed)
        let controls: Vec<ChanControl> =
            lanes.iter().map(|(on, _)| on.control()).collect();
        // one trace sink per party, shared with the link cores: the
        // first service on a trio installs it, later slots adopt it.
        // Enabling from link birth is what makes the flight-byte
        // reconciliation against Stats exact (OPERATIONS.md §3).
        let sinks: Vec<Arc<crate::trace::TraceSink>> = lanes.iter()
            .map(|(on, _)| {
                let s = Arc::new(crate::trace::TraceSink::new());
                if on.install_tracer(Arc::clone(&s)) {
                    s
                } else {
                    on.tracer_handle().expect("sink just rejected")
                }
            })
            .collect();
        if cfg.trace {
            for s in &sinks {
                s.set_enabled(true);
            }
        }
        let mut banks: Vec<Arc<TupleBank>> = Vec::with_capacity(3);
        for _ in 0..3 {
            banks.push(Arc::new(TupleBank::try_new(bank_cfg)
                .map_err(|e| anyhow!("bank config: {e}"))?));
        }
        let (logits_tx, logits_rx) = channel();
        let mut job_txs = Vec::new();
        let mut handles = Vec::new();
        let (ready_tx, ready_rx) = channel();
        for ((comm, off_comm), bank) in
            lanes.into_iter().zip(banks.iter().cloned()) {
            let model = Arc::clone(&model);
            let plan = plan.clone();
            let cfg = cfg.clone();
            let logits_tx = logits_tx.clone();
            let ready_tx = ready_tx.clone();
            let (jtx, jrx) = channel::<Job>();
            job_txs.push(jtx);
            handles.push(thread::spawn(move || -> Stats {
                let seeds = PartySeeds::setup(seed, comm.id);
                let ctx = Ctx::with_cfg(&comm, &seeds, cfg.proto);
                // build the backend, warming the PJRT executable cache
                // before the first request (warmup is a no-op for native)
                let backend: Box<dyn crate::protocols::linear::LinearBackend> =
                    match make_backend(cfg.backend, &cfg.hlo_dir) {
                        Ok(b) => b,
                        Err(e) => {
                            let _ = ready_tx.send(
                                Err(anyhow!("backend: {e}")));
                            return comm.stats();
                        }
                    };
                backend.warmup(&crate::engine::hlo_keys(&model));
                let shared: SharedModel =
                    match share_model(&ctx, &model, comm.id == 1) {
                        Ok(s) => s,
                        Err(e) => {
                            let _ = ready_tx.send(Err(anyhow!("share: {e}")));
                            return comm.stats();
                        }
                    };
                // background tuple producer: its own thread, its own PRF
                // domain, this model's offline lane of the same links.
                // Refill jobs are forwarded to it so minting overlaps
                // with online inference instead of riding the request.
                let (prod_tx, prod_rx) = channel::<usize>();
                let producer = off_comm.map(|off_comm| {
                    let off_seeds = offline_seeds(seed, comm.id);
                    let proto = cfg.proto;
                    let pbank = Arc::clone(&bank);
                    thread::spawn(move || {
                        let octx = Ctx::with_cfg(&off_comm, &off_seeds,
                                                 proto);
                        if let Err(e) = run_producer(&octx, pbank.as_ref(),
                                                     prod_rx) {
                            eprintln!("[service {}] offline producer \
                                       failed: {e}", off_comm.id);
                            pbank.close();
                        }
                    })
                });
                let _ = ready_tx.send(Ok(comm.id));
                while let Ok(job) = jrx.recv() {
                    match job {
                        Job::Shutdown => break,
                        Job::Die => return comm.stats(),
                        Job::Refill(n) => {
                            // credit in broadcast order (deterministic
                            // across parties), then hand the mint to the
                            // background producer
                            bank.credit(n);
                            let _ = prod_tx.send(n);
                        }
                        Job::Retune { low, high, chunk } => {
                            // validated at dispatch; a stale-capacity
                            // race would reject identically on all
                            // parties (capacity never changes)
                            let _ = bank.retune(low, high, chunk);
                        }
                        Job::Infer { inputs, batch, trace, label } => {
                            crate::trace::set_current_trace(trace);
                            let cur = comm.tracer()
                                .filter(|t| t.enabled())
                                .map(|t| t.cursor(&comm));
                            let src = if cfg.opts.preprocess {
                                TupleSource::Bank(bank.as_ref())
                            } else {
                                TupleSource::Inline
                            };
                            let r = match &plan {
                                Some(p) => crate::engine::fusion::
                                    infer_batch_fused(
                                        &ctx, &shared, p, backend.as_ref(),
                                        cfg.opts, &inputs, batch, &src),
                                None => infer_batch_pooled(
                                    &ctx, &shared, backend.as_ref(),
                                    cfg.opts, &inputs, batch, &src),
                            };
                            if let Some(cur) = cur {
                                if let Some(tr) = comm.tracer() {
                                    tr.close(
                                        &comm,
                                        crate::trace::SpanKind::Request,
                                        0,
                                        label.as_deref()
                                            .unwrap_or(&model.name),
                                        &cur);
                                }
                            }
                            crate::trace::set_current_trace(0);
                            let failed = r.is_err();
                            if comm.id == 0 {
                                let _ = logits_tx.send(
                                    r.map(|o| o.logits)
                                     .map_err(|e| anyhow!("{e}")));
                            } else if let Err(e) = &r {
                                eprintln!("[service {}] inference failed: \
                                           {e}", comm.id);
                            }
                            if failed {
                                // a failed protocol leaves the trio
                                // desynchronized; retire this party --
                                // dropping its Comm unblocks any peer
                                // stuck in recv with WireError::Closed
                                // instead of hanging the Service
                                break;
                            }
                        }
                    }
                }
                // graceful drain: wake any backpressured delivery, let
                // the producer finish its queued chunks (identical on
                // all parties, so the interactive mints complete), and
                // join it before this party's links drop
                bank.close();
                drop(prod_tx);
                if let Some(h) = producer {
                    let _ = h.join();
                }
                comm.stats()
            }));
        }
        let t0 = Instant::now();
        for _ in 0..3 {
            ready_rx.recv().map_err(|_| anyhow!("party died in setup"))??;
        }
        let svc = Service {
            sched: Mutex::new(Sched { txs: job_txs, dispatched: 0 }),
            logits_rx: Mutex::new(logits_rx),
            handles: Mutex::new(handles),
            joined: Mutex::new(None),
            cancelled: AtomicBool::new(false),
            controls,
            banks,
            bank_cfg,
            preprocess: cfg.opts.preprocess,
            plan,
            sinks,
            latency: Mutex::new(Histogram::default()),
            slot,
            epoch,
            model_name: model.name.clone(),
            model,
            setup_time: t0.elapsed(),
        };
        // offline prefill: reach the high watermark before serving, so
        // the first request already runs the 2-round online MSB
        if svc.preprocess {
            svc.top_up_to(svc.bank_cfg.high);
            for b in &svc.banks {
                b.wait_level(svc.bank_cfg.high)
                    .map_err(|e| anyhow!("offline prefill: {e}"))?;
            }
        }
        Ok(svc)
    }

    /// MSB tuple demand of one `batch`-sized request (public manifest
    /// arithmetic; the pump's refill unit).  Follows the fused plan when
    /// fusion is on -- folded signs and OR-pools draw nothing.
    pub fn demand_for(&self, batch: usize) -> usize {
        match &self.plan {
            Some(p) => p.msb_demand(batch),
            None => msb_demand_for(&self.model, batch),
        }
    }

    /// Largest single MSB draw a `batch`-sized request makes.  Draws
    /// above `capacity - chunk` always fall back (deadlock freedom), so
    /// the batcher checks this against the bank at startup.
    pub fn max_draw_for(&self, batch: usize) -> usize {
        let sizes = match &self.plan {
            Some(p) => p.msb_sizes(batch),
            None => crate::engine::msb_sizes_of(&self.model.ops,
                                                self.model.input, batch),
        };
        sizes.into_iter().max().unwrap_or(0)
    }

    /// Party `i`'s tuple bank (observability: levels and
    /// `PreprocMetrics`; all parties' banks evolve identically).
    pub fn bank_handle(&self, party: usize) -> Arc<TupleBank> {
        Arc::clone(&self.banks[party])
    }

    /// The watermark pump: when deterministic headroom (dispatched minus
    /// reserved elements) is below the low watermark or below
    /// `target_elems`, broadcast chunk-sized refill jobs until it reaches
    /// `max(target_elems, high)` (clamped to capacity).  Deterministic:
    /// refills share the infer broadcast lock, so every party folds them
    /// into its credit accounting at the same point of the job order.
    pub fn top_up_to(&self, target_elems: usize) {
        if !self.preprocess {
            return;
        }
        // the *live* watermarks (party 0's view; retunes ride the same
        // broadcast queue as these refills, so a just-dispatched resize
        // is at worst one pump tick stale -- credits are explicit in
        // the jobs, so staleness never desynchronizes accounting)
        let bc = self.banks[0].config();
        let goal = target_elems.max(bc.high).min(bc.capacity);
        let mut sched = recover(self.sched.lock());
        let reserved = self.banks[0].reserved_elems();
        let mut avail = sched.dispatched.saturating_sub(reserved);
        if avail >= bc.low && avail >= target_elems {
            return;
        }
        while avail < goal {
            for tx in &sched.txs {
                let _ = tx.send(Job::Refill(bc.chunk));
            }
            sched.dispatched += bc.chunk;
            avail += bc.chunk;
        }
    }

    /// Broadcast an adaptive watermark resize to all three parties'
    /// banks (`Job::Retune`, applied in job order -- see the variant
    /// doc for why this cannot be a direct bank call).  Validated here
    /// against the immutable capacity so an infeasible resize is
    /// rejected before anything is enqueued.  No-op without
    /// preprocessing.  Called from the batcher's dispatch thread only,
    /// never the request path.
    pub fn retune_banks(&self, low: usize, high: usize, chunk: usize)
                        -> Result<(), String> {
        if !self.preprocess {
            return Ok(());
        }
        let capacity = self.banks[0].config().capacity;
        BankConfig { low, high, chunk, capacity }.validate()?;
        let sched = recover(self.sched.lock());
        for tx in &sched.txs {
            let _ = tx.send(Job::Retune { low, high, chunk });
        }
        Ok(())
    }

    /// Admission-control probe: can a `batch`-sized request be served
    /// from a warm bank?  `false` means its largest MSB draw would
    /// *always* fall back to a request-path mint (bank closed, or draw
    /// above `capacity - chunk`), which is exactly when the batcher
    /// sheds instead of admitting.  Non-mutating -- a shed counts no
    /// underflow, because the request never reaches the request path.
    /// Always `true` without preprocessing (nothing to mint).
    pub fn can_serve_warm(&self, batch: usize) -> bool {
        !self.preprocess
            || self.banks[0]
                .can_serve_warm(self.max_draw_for(batch.max(1)))
    }

    /// Run one batch through the session (blocking).  Over a service's
    /// own links a failed protocol surfaces as `Err` (the failing
    /// party's retirement drops the link cores and `Closed` unblocks
    /// its peers); in a registry the shared links outlive one lane's
    /// threads, so a *partial* lane failure leaves this call blocked
    /// until [`ModelRegistry::quarantine`] retires the slot's lanes --
    /// at which point it returns `Err` instead of hanging.
    pub fn infer(&self, inputs: Vec<Tensor>) -> Result<Vec<Vec<i32>>> {
        self.infer_labeled(inputs, None)
    }

    /// `infer` with a Request-span label override: the request plane
    /// passes `trace::request_label(model, slot, tenants)` so traces
    /// attribute each batch to its tenants and shard.  Label handling
    /// is the only difference -- the broadcast path, job order, and
    /// therefore the logits are identical to unlabeled `infer`.
    pub fn infer_labeled(&self, inputs: Vec<Tensor>,
                         label: Option<String>) -> Result<Vec<Vec<i32>>> {
        let batch = inputs.len();
        // every request gets a trace id whether or not tracing is on:
        // minting is one relaxed fetch_add, and the id in the job is
        // what lets `trace on` mid-run attribute the very next batch
        let trace = crate::trace::next_trace_id();
        // keep the bank at its own watermarks even without a Coordinator
        // in front: the refill jobs land ahead of this infer in every
        // party's queue (same broadcast lock), so the producers overlap
        // this batch instead of draining the prefill dry
        self.top_up_to(0);
        let t0 = Instant::now();
        let rx = recover(self.logits_rx.lock());
        {
            let sched = recover(self.sched.lock());
            for (id, tx) in sched.txs.iter().enumerate() {
                let job = Job::Infer {
                    inputs: if id == 0 { inputs.clone() } else { vec![] },
                    batch,
                    trace,
                    label: label.clone(),
                };
                tx.send(job).map_err(|_| anyhow!("party {id} gone"))?;
            }
        }
        let out = rx.recv().map_err(|_| anyhow!("no response"))?;
        if out.is_ok() {
            recover(self.latency.lock()).record(t0.elapsed());
        }
        out
    }

    /// Ask every party thread to stop once its queued jobs are done
    /// (the graceful half of `shutdown`).
    fn request_stop(&self) {
        let sched = recover(self.sched.lock());
        for tx in &sched.txs {
            let _ = tx.send(Job::Shutdown);
        }
    }

    /// Forcefully cancel this service: drain+close its banks (waking
    /// backpressured producers and blocked draws), ask the party
    /// threads to stop, and retire both of its lanes on every party --
    /// which turns any recv blocked mid-protocol into
    /// `WireError::Closed`, so a desynchronized slot's threads unwind
    /// instead of hanging on the shared links.  Idempotent; pair with
    /// [`Service::join_parties`] (or call [`Service::abort`]).
    pub fn cancel(&self) {
        if self.cancelled.swap(true, Ordering::SeqCst) {
            return;
        }
        for b in &self.banks {
            let _ = b.drain();
        }
        self.request_stop();
        for ctl in &self.controls {
            ctl.close_chan(ChanId::online(self.slot));
            ctl.close_chan(ChanId::offline(self.slot));
        }
    }

    /// Join the party threads and collect their comm stats, typed: a
    /// panicked thread surfaces as an error instead of a silent
    /// default.  Idempotent (the first join's stats are cached).  In a
    /// registry the stats are *link-wide* (the cores are shared); use
    /// `Stats::chan`/`Stats::model` with this service's `slot` for its
    /// own rows.
    pub fn join_parties(&self) -> Result<[Stats; 3]> {
        if let Some((stats, err)) = recover(self.joined.lock()).clone() {
            return match err {
                None => Ok(stats),
                Some(e) => Err(anyhow!(e)),
            };
        }
        let handles: Vec<_> = {
            let mut h = recover(self.handles.lock());
            h.drain(..).collect()
        };
        if handles.len() != 3 {
            return Err(anyhow!(
                "party threads already being joined elsewhere"));
        }
        // join ALL three before reporting: stopping at the first panic
        // would detach the remaining threads and lose their stats
        let mut stats = Vec::with_capacity(3);
        let mut panicked = Vec::new();
        for (i, h) in handles.into_iter().enumerate() {
            match h.join() {
                Ok(s) => stats.push(s),
                Err(_) => {
                    panicked.push(i);
                    stats.push(Stats::default());
                }
            }
        }
        let arr: [Stats; 3] = stats.try_into().map_err(|_| anyhow!(
            "expected exactly three party threads"))?;
        let err = (!panicked.is_empty()).then(|| format!(
            "party thread(s) {panicked:?} panicked during drain (their \
             stats rows are empty)"));
        *recover(self.joined.lock()) = Some((arr.clone(), err.clone()));
        match err {
            None => Ok(arr),
            Some(e) => Err(anyhow!(e)),
        }
    }

    /// Graceful stop: queued batches finish, producers drain, then the
    /// party threads are joined.  Only safe while the trio is healthy
    /// (a desynchronized slot must be [`Service::abort`]ed -- its
    /// threads never reach their queues).
    pub fn shutdown(&self) -> Result<[Stats; 3]> {
        self.request_stop();
        self.join_parties()
    }

    /// Forceful stop: [`Service::cancel`] then join.  The quarantine
    /// path -- works even with party threads blocked mid-protocol.
    pub fn abort(&self) -> Result<[Stats; 3]> {
        self.cancel();
        self.join_parties()
    }

    /// Fault injection for tests and ops drills: abruptly kill one
    /// party thread (it exits without the graceful drain, exactly like
    /// a crashed thread), leaving its peers blocked mid-protocol on the
    /// shared links.  Pair with [`ModelRegistry::quarantine`] to
    /// exercise recovery.
    pub fn inject_fault(&self, party: usize) {
        let sched = recover(self.sched.lock());
        let _ = sched.txs[party].send(Job::Die);
    }

    /// Fault injection: retire this service's online lane on one party
    /// only, so that party's next protocol recv dies mid-batch while
    /// its peers block -- the lane-desync shape the quarantine path
    /// exists for.
    pub fn sever_lane(&self, party: usize) {
        self.controls[party].close_chan(ChanId::online(self.slot));
    }

    /// Party `party`'s trace sink (shared across every slot of the
    /// trio in a registry).
    pub fn trace_sink(&self, party: usize)
                      -> Arc<crate::trace::TraceSink> {
        Arc::clone(&self.sinks[party])
    }

    /// A weak handle on party `party`'s links (stats for the trace
    /// sidecar after the service itself has been consumed, e.g. by a
    /// `Coordinator`).
    pub fn chan_control(&self, party: usize) -> ChanControl {
        self.controls[party].clone()
    }

    /// Toggle span recording on every party's sink (the admin REPL's
    /// `trace on|off`).  Turning tracing on mid-run yields a *partial*
    /// trace: flight bytes recorded from that point on no longer sum
    /// to the link's lifetime `Stats` (OPERATIONS.md §3 documents the
    /// caveat; start with `--trace-out` for reconcilable traces).
    pub fn set_tracing(&self, on: bool) {
        for s in &self.sinks {
            s.set_enabled(on);
        }
    }

    /// Whether any party is currently recording spans.
    pub fn tracing(&self) -> bool {
        self.sinks.iter().any(|s| s.enabled())
    }

    /// Snapshot of the request-latency histogram (admin `stats`).
    pub fn latency(&self) -> Histogram {
        recover(self.latency.lock()).clone()
    }

    /// Export every party's trace (`trace-p<N>.jsonl`) and stats
    /// sidecar (`stats-p<N>.json`) under `dir`.  The sidecar carries
    /// the *link-wide* stats -- in a registry that spans every slot,
    /// exactly like the shared sinks do.
    pub fn write_traces(&self, dir: &std::path::Path) -> Result<()> {
        for (party, (sink, ctl)) in
            self.sinks.iter().zip(&self.controls).enumerate() {
            let stats = ctl.stats().unwrap_or_default();
            crate::trace::write_party_trace(dir, party, sink, &stats)
                .map_err(|e| anyhow!("trace export: {e}"))?;
        }
        Ok(())
    }
}

/// One model entry for [`ModelRegistry::start`]: a unique name (the
/// routing key), the manifest-loaded model, and an optional per-model
/// bank override (`None` auto-scales via `BankConfig::auto` to the
/// model's own demand at the session's `max_batch`).
pub struct ModelSpec {
    pub name: String,
    pub model: Arc<Model>,
    pub bank: Option<BankConfig>,
}

impl ModelSpec {
    pub fn new(name: impl Into<String>, model: Arc<Model>) -> ModelSpec {
        ModelSpec { name: name.into(), model, bank: None }
    }
}

/// Lifecycle state of one registry slot.  The machine is `Starting ->
/// Serving -> Draining -> Quarantined -> (respawn) Starting -> Serving`;
/// `remove_model` leaves from `Serving` (via `Draining`) or
/// `Quarantined`, returning the slot id to the free list.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SlotState {
    /// A service is being brought up for this slot (add/respawn).
    Starting,
    /// Healthy: routing `infer` by name.
    Serving,
    /// Lifecycle transition in progress: quiescing (remove) or
    /// cancelling (quarantine).
    Draining,
    /// Cancelled after a desync: lanes retired, bank drained, threads
    /// joined.  `respawn` restarts it; `remove_model` frees the slot.
    Quarantined,
}

impl std::fmt::Display for SlotState {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            SlotState::Starting => "starting",
            SlotState::Serving => "serving",
            SlotState::Draining => "draining",
            SlotState::Quarantined => "quarantined",
        };
        write!(f, "{s}")
    }
}

/// Typed registry failure: what was wrong with a spec list, a lookup,
/// or a lifecycle transition, inspectable by callers (the CLI maps
/// these to flag hints / admin messages).
#[derive(Debug)]
pub enum RegistryError {
    /// `start` needs at least one model spec.
    Empty,
    /// Two specs share a name; the name is the routing key.
    DuplicateModel(String),
    /// More models than the channel-id space has slots.
    TooManyModels { count: usize, max: usize },
    /// `infer`/`service` lookup for a name nobody registered.
    UnknownModel(String),
    /// A model's `Service` failed to start or serve.
    Service { model: String, source: anyhow::Error },
    /// The slot exists but is not in a state the operation accepts
    /// (e.g. `infer` on a quarantined model, `respawn` on a serving
    /// one).
    SlotUnavailable { model: String, state: SlotState },
    /// A drain/join failed (party thread panicked) -- the slot's state
    /// transition still happened; the detail says what was lost.
    Drain { model: String, detail: String },
    /// Load shed at admission: the batcher refused the request *before*
    /// it could reach the request path, because the queue is full or
    /// the tuple bank cannot serve the batch warm.  Typed so clients
    /// can tell "retry later" (this) apart from "the model is broken"
    /// (`Service`/`SlotUnavailable`).  By construction a shed request
    /// never minted: `underflow_calls` stays 0.
    Overloaded { model: String, reason: ShedReason },
}

impl std::fmt::Display for RegistryError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RegistryError::Empty =>
                write!(f, "registry needs at least one model spec"),
            RegistryError::DuplicateModel(n) =>
                write!(f, "duplicate model name '{n}': registry names \
                           are routing keys and must be unique"),
            RegistryError::TooManyModels { count, max } =>
                write!(f, "{count} models exceed the {max}-slot channel \
                           id space"),
            RegistryError::UnknownModel(n) =>
                write!(f, "no model named '{n}' in the registry"),
            RegistryError::Service { model, source } =>
                write!(f, "model '{model}': {source}"),
            RegistryError::SlotUnavailable { model, state } =>
                write!(f, "model '{model}' is {state}, not serving this \
                           operation"),
            RegistryError::Drain { model, detail } =>
                write!(f, "model '{model}' drain: {detail}"),
            RegistryError::Overloaded { model, reason } =>
                write!(f, "model '{model}' overloaded: {reason}"),
        }
    }
}

impl std::error::Error for RegistryError {}

/// One registry slot's bookkeeping: the occupying model, its lifecycle
/// state, and (while serving) the live service.
struct Entry {
    name: String,
    model: Arc<Model>,
    bank: Option<BankConfig>,
    slot: u8,
    epoch: u32,
    state: SlotState,
    service: Option<Arc<Service>>,
    /// Consecutive `infer` failures since the last success (the
    /// auto-quarantine watchdog's input; reset on success and respawn).
    consec_errors: u32,
}

/// Interior registry state, one lock: lifecycle transitions hold it
/// only briefly (never across a blocking batch or a service start), so
/// healthy models keep serving while one slot churns.
struct Inner {
    entries: Vec<Entry>,
    /// Slot ids retired by `remove_model`, reused lowest-first.
    free_slots: Vec<u8>,
    /// Next never-used slot id.
    next_slot: u8,
    /// Per-slot lifecycle counters, surviving the models that occupy
    /// the slot.
    lifecycle: BTreeMap<u8, LifecycleCounters>,
}

impl Inner {
    fn entry_mut(&mut self, name: &str)
                 -> Result<&mut Entry, RegistryError> {
        self.entries.iter_mut()
            .find(|e| e.name == name)
            .ok_or_else(|| RegistryError::UnknownModel(name.to_string()))
    }
}

/// N per-model [`Service`]s multiplexed over *one* process's three
/// links: the multi-model serving front.  Each model slot gets its own
/// channel-id lane pair, PRF seed domain, `TupleBank`, and producer
/// lane; requests route by model name.  Initial slots are assigned in
/// spec order, so a given spec list is reproducible run-to-run (and
/// against `Service::start_at` reference arms); hot-added models reuse
/// freed slot ids lowest-first.  All lifecycle operations take `&self`
/// (state is behind one internal lock), so an admin thread can
/// quarantine a stuck slot while other threads keep serving -- and
/// while a request is blocked *on* that slot.
pub struct ModelRegistry {
    links: [Comm; 3],
    cfg: SessionConfig,
    /// Per-party trace sinks, installed on the link cores before any
    /// slot starts (so every model's services adopt the same sinks and
    /// flight bytes reconcile link-wide).
    sinks: Vec<Arc<crate::trace::TraceSink>>,
    inner: Mutex<Inner>,
}

impl ModelRegistry {
    /// Bring up every model's service over one fresh link trio,
    /// sequentially (model sharing and bank prefill are interactive;
    /// one model's setup completes before the next begins).  Spec
    /// validation -- non-empty, unique names, at most
    /// `ChanId::MAX_MODELS` -- happens before any thread spawns.
    pub fn start(specs: Vec<ModelSpec>, cfg: &SessionConfig)
                 -> Result<ModelRegistry, RegistryError> {
        if specs.is_empty() {
            return Err(RegistryError::Empty);
        }
        if specs.len() > ChanId::MAX_MODELS {
            return Err(RegistryError::TooManyModels {
                count: specs.len(),
                max: ChanId::MAX_MODELS,
            });
        }
        let mut seen = std::collections::BTreeSet::new();
        for spec in &specs {
            if !seen.insert(spec.name.clone()) {
                return Err(RegistryError::DuplicateModel(
                    spec.name.clone()));
            }
        }
        let links = local_trio(cfg.net);
        for c in &links {
            c.set_parked_cap(cfg.max_parked_bytes);
        }
        // install the per-party sinks before any slot exists: every
        // model's service adopts them, and a trace enabled from link
        // birth reconciles its flight bytes exactly against the link
        // Stats
        let sinks: Vec<Arc<crate::trace::TraceSink>> = links.iter()
            .map(|c| {
                let s = Arc::new(crate::trace::TraceSink::new());
                if c.install_tracer(Arc::clone(&s)) {
                    s
                } else {
                    c.tracer_handle().expect("sink just rejected")
                }
            })
            .collect();
        if cfg.trace {
            for s in &sinks {
                s.set_enabled(true);
            }
        }
        let reg = ModelRegistry {
            links,
            cfg: cfg.clone(),
            sinks,
            inner: Mutex::new(Inner {
                entries: Vec::with_capacity(specs.len()),
                free_slots: Vec::new(),
                next_slot: specs.len() as u8,
                lifecycle: BTreeMap::new(),
            }),
        };
        for (slot, spec) in specs.into_iter().enumerate() {
            let svc = reg.start_slot(&spec.model, spec.bank, slot as u8, 0)
                .map_err(|e| RegistryError::Service {
                    model: spec.name.clone(),
                    source: e,
                })?;
            recover(reg.inner.lock()).entries.push(Entry {
                name: spec.name,
                model: spec.model,
                bank: spec.bank,
                slot: slot as u8,
                epoch: 0,
                state: SlotState::Serving,
                service: Some(Arc::new(svc)),
                consec_errors: 0,
            });
        }
        Ok(reg)
    }

    /// Bring up one slot's service over the shared links (the
    /// start/add/respawn workhorse; never called with the inner lock
    /// held -- setup is interactive and healthy slots must keep
    /// serving).
    fn start_slot(&self, model: &Arc<Model>, bank: Option<BankConfig>,
                  slot: u8, epoch: u32) -> Result<Service> {
        let mut mcfg = self.cfg.clone();
        mcfg.bank = bank.or(self.cfg.bank);
        let comms =
            [self.links[0].clone(), self.links[1].clone(),
             self.links[2].clone()];
        Service::start_on_epoch(Arc::clone(model), mcfg, comms, slot,
                                epoch)
    }

    /// Registered model names (any state), in slot order.
    pub fn names(&self) -> Vec<String> {
        let inner = recover(self.inner.lock());
        let mut rows: Vec<(u8, String)> = inner.entries.iter()
            .map(|e| (e.slot, e.name.clone())).collect();
        rows.sort();
        rows.into_iter().map(|(_, n)| n).collect()
    }

    /// Every slot's (name, slot, state, epoch), in slot order -- the
    /// admin `status` view.
    pub fn status(&self) -> Vec<(String, u8, SlotState, u32)> {
        let inner = recover(self.inner.lock());
        let mut rows: Vec<_> = inner.entries.iter()
            .map(|e| (e.name.clone(), e.slot, e.state, e.epoch))
            .collect();
        rows.sort_by_key(|r| r.1);
        rows
    }

    /// The current lifecycle state of `name`'s slot.
    pub fn state(&self, name: &str) -> Result<SlotState, RegistryError> {
        let mut inner = recover(self.inner.lock());
        Ok(inner.entry_mut(name)?.state)
    }

    /// Per-slot lifecycle counters (quarantines, respawns, swaps),
    /// keyed by slot id; slots that never churned have no entry.
    pub fn lifecycle_counters(&self) -> BTreeMap<u8, LifecycleCounters> {
        recover(self.inner.lock()).lifecycle.clone()
    }

    /// The live service bound to `name` (must be `Serving`).
    pub fn service(&self, name: &str)
                   -> Result<Arc<Service>, RegistryError> {
        let mut inner = recover(self.inner.lock());
        let e = inner.entry_mut(name)?;
        match (&e.service, e.state) {
            (Some(svc), SlotState::Serving) => Ok(Arc::clone(svc)),
            _ => Err(RegistryError::SlotUnavailable {
                model: name.to_string(),
                state: e.state,
            }),
        }
    }

    /// Route one batch to `name`'s service (blocking).  The registry
    /// lock is released before the batch runs, so other models -- and
    /// lifecycle operations on *this* model -- proceed concurrently.
    ///
    /// **Auto-quarantine watchdog.**  Consecutive failures on one slot
    /// are counted (successes reset the count); on reaching the
    /// configured threshold (`SessionConfig::max_consecutive_errors`,
    /// default 3, 0 disables) the slot is force-quarantined so a wedged
    /// or desynchronized model stops eating requests -- subsequent
    /// `infer`s get `SlotUnavailable` until an operator `respawn`s it.
    /// Trips are counted in `LifecycleCounters::watchdog_trips`.
    pub fn infer(&self, name: &str, inputs: Vec<Tensor>)
                 -> Result<Vec<Vec<i32>>, RegistryError> {
        let svc = self.service(name)?;
        match svc.infer(inputs) {
            Ok(logits) => {
                let mut inner = recover(self.inner.lock());
                if let Ok(e) = inner.entry_mut(name) {
                    e.consec_errors = 0;
                }
                Ok(logits)
            }
            Err(e) => {
                let threshold = self.cfg.max_consecutive_errors;
                let trip = {
                    let mut inner = recover(self.inner.lock());
                    match inner.entry_mut(name) {
                        Ok(en) => {
                            en.consec_errors =
                                en.consec_errors.saturating_add(1);
                            (threshold > 0
                             && en.consec_errors >= threshold)
                                .then_some(en.slot)
                        }
                        Err(_) => None, // removed concurrently
                    }
                };
                if let Some(slot) = trip {
                    // force-quarantine; the trip is recorded whatever
                    // the drain reported (the state transition happened)
                    let _ = self.quarantine(name);
                    recover(self.inner.lock()).lifecycle
                        .entry(slot).or_default().watchdog_trips += 1;
                }
                Err(RegistryError::Service {
                    model: name.to_string(),
                    source: e,
                })
            }
        }
    }

    /// Cancel one slot after a desync (`Serving -> Draining ->
    /// Quarantined`): retire its lanes at the transport (any request
    /// blocked on it errs instead of hanging), join its party threads,
    /// drain+drop its banks.  The other slots sharing the links are
    /// untouched.  `respawn` restarts it; `remove_model` frees it.
    pub fn quarantine(&self, name: &str) -> Result<(), RegistryError> {
        let svc = {
            let mut inner = recover(self.inner.lock());
            let e = inner.entry_mut(name)?;
            if e.state != SlotState::Serving {
                return Err(RegistryError::SlotUnavailable {
                    model: name.to_string(),
                    state: e.state,
                });
            }
            let Some(svc) = e.service.clone() else {
                return Err(RegistryError::Drain {
                    model: name.to_string(),
                    detail: "serving slot has no service handle".into(),
                });
            };
            e.state = SlotState::Draining;
            svc
        };
        let joined = svc.abort();
        let mut inner = recover(self.inner.lock());
        let slot = {
            let e = inner.entry_mut(name)?;
            e.state = SlotState::Quarantined;
            e.service = None; // drops the drained banks with the service
            e.slot
        };
        inner.lifecycle.entry(slot).or_default().quarantines += 1;
        joined.map(|_| ()).map_err(|err| RegistryError::Drain {
            model: name.to_string(),
            detail: err.to_string(),
        })
    }

    /// Restart a quarantined slot on the same `ChanId` lanes under a
    /// fresh seed epoch (`Quarantined -> Starting -> Serving`).  Stale
    /// frames of the dead epoch are swept off the links before the
    /// lanes re-open; the sweep is best-effort (`Comm::sweep` documents
    /// the residual race and its containment -- a misdelivered stale
    /// frame desyncs the new epoch, which is simply quarantined again).
    pub fn respawn(&self, name: &str) -> Result<(), RegistryError> {
        let (model, bank, slot, epoch) = {
            let mut inner = recover(self.inner.lock());
            let e = inner.entry_mut(name)?;
            if e.state != SlotState::Quarantined {
                return Err(RegistryError::SlotUnavailable {
                    model: name.to_string(),
                    state: e.state,
                });
            }
            e.state = SlotState::Starting;
            (Arc::clone(&e.model), e.bank, e.slot, e.epoch + 1)
        };
        for c in &self.links {
            c.sweep();
        }
        let started = self.start_slot(&model, bank, slot, epoch);
        let mut inner = recover(self.inner.lock());
        match started {
            Ok(svc) => {
                {
                    let e = inner.entry_mut(name)?;
                    e.service = Some(Arc::new(svc));
                    e.state = SlotState::Serving;
                    e.epoch = epoch;
                    e.consec_errors = 0; // fresh epoch, clean slate
                }
                let lc = inner.lifecycle.entry(slot).or_default();
                lc.respawns += 1;
                lc.epoch = epoch;
                Ok(())
            }
            Err(err) => {
                inner.entry_mut(name)?.state = SlotState::Quarantined;
                Err(RegistryError::Service {
                    model: name.to_string(),
                    source: err,
                })
            }
        }
    }

    /// Hot-add a model to the live registry: the lowest freed slot id
    /// is reused (else the next fresh one), the service is brought up
    /// on its lanes, and the name routes once it is `Serving`.  Returns
    /// the slot id.
    pub fn add_model(&self, spec: ModelSpec)
                     -> Result<u8, RegistryError> {
        let slot = {
            let mut inner = recover(self.inner.lock());
            if inner.entries.iter().any(|e| e.name == spec.name) {
                return Err(RegistryError::DuplicateModel(spec.name));
            }
            let slot = if inner.free_slots.is_empty() {
                if inner.next_slot as usize >= ChanId::MAX_MODELS {
                    return Err(RegistryError::TooManyModels {
                        count: inner.entries.len() + 1,
                        max: ChanId::MAX_MODELS,
                    });
                }
                let s = inner.next_slot;
                inner.next_slot += 1;
                s
            } else {
                // sorted ascending: index 0 is the lowest freed id
                inner.free_slots.remove(0)
            };
            inner.entries.push(Entry {
                name: spec.name.clone(),
                model: Arc::clone(&spec.model),
                bank: spec.bank,
                slot,
                epoch: 0,
                state: SlotState::Starting,
                service: None,
                consec_errors: 0,
            });
            slot
        };
        // a reused slot may have dead-epoch frames still queued on the
        // links (a quarantined-then-removed occupant): sweep before the
        // lanes re-open, exactly like respawn does
        for c in &self.links {
            c.sweep();
        }
        let started = self.start_slot(&spec.model, spec.bank, slot, 0);
        let mut inner = recover(self.inner.lock());
        match started {
            Ok(svc) => {
                {
                    let e = inner.entry_mut(&spec.name)?;
                    e.service = Some(Arc::new(svc));
                    e.state = SlotState::Serving;
                }
                let lc = inner.lifecycle.entry(slot).or_default();
                lc.swaps_in += 1;
                lc.epoch = 0;
                Ok(slot)
            }
            Err(err) => {
                inner.entries.retain(|e| e.name != spec.name);
                inner.free_slots.push(slot);
                inner.free_slots.sort_unstable();
                Err(RegistryError::Service {
                    model: spec.name,
                    source: err,
                })
            }
        }
    }

    /// Hot-remove a model from the live registry: a serving slot is
    /// quiesced (`Serving -> Draining`: queued batches finish, the
    /// producers drain), its lanes are retired with their parked frames
    /// purged at the demux, and the slot id returns to the free list.
    /// A quarantined slot (lanes already retired) is simply freed.
    pub fn remove_model(&self, name: &str) -> Result<(), RegistryError> {
        let svc = {
            let mut inner = recover(self.inner.lock());
            let e = inner.entry_mut(name)?;
            match e.state {
                SlotState::Serving => {
                    e.state = SlotState::Draining;
                    e.service.clone()
                }
                SlotState::Quarantined => {
                    // claim the slot while unlocked below: a concurrent
                    // respawn must not revive it mid-removal (two live
                    // services on one lane pair)
                    e.state = SlotState::Draining;
                    None
                }
                state => {
                    return Err(RegistryError::SlotUnavailable {
                        model: name.to_string(),
                        state,
                    });
                }
            }
        };
        let mut drain_err = None;
        if let Some(svc) = &svc {
            // quiesce-then-close: the graceful drain finishes queued
            // batches before the threads exit; only then are the lanes
            // retired (closing them first would kill those batches)
            if let Err(e) = svc.shutdown() {
                drain_err = Some(e.to_string());
            }
            for c in &self.links {
                c.close_chan(ChanId::online(svc.slot));
                c.close_chan(ChanId::offline(svc.slot));
            }
        }
        let mut inner = recover(self.inner.lock());
        let slot = inner.entry_mut(name)?.slot;
        inner.entries.retain(|e| e.name != name);
        inner.free_slots.push(slot);
        inner.free_slots.sort_unstable();
        inner.lifecycle.entry(slot).or_default().swaps_out += 1;
        match drain_err {
            None => Ok(()),
            Some(detail) => Err(RegistryError::Drain {
                model: name.to_string(),
                detail,
            }),
        }
    }

    /// Party `party`'s link-wide comm stats (totals plus every model
    /// lane's `ChanStats` row; rows sum to the totals).
    pub fn link_stats(&self, party: usize) -> Stats {
        self.links[party].stats()
    }

    /// Party `party`'s trace sink (shared by every slot of the links).
    pub fn trace_sink(&self, party: usize)
                      -> Arc<crate::trace::TraceSink> {
        Arc::clone(&self.sinks[party])
    }

    /// Toggle span recording on all three parties' sinks (the admin
    /// REPL's `trace on|off`; see `Service::set_tracing` for the
    /// mid-run partial-trace caveat).
    pub fn set_tracing(&self, on: bool) {
        for s in &self.sinks {
            s.set_enabled(on);
        }
    }

    /// Whether any party is currently recording spans.
    pub fn tracing(&self) -> bool {
        self.sinks.iter().any(|s| s.enabled())
    }

    /// Export every party's trace (`trace-p<N>.jsonl`) and stats
    /// sidecar (`stats-p<N>.json`) under `dir`; the sidecars carry the
    /// link-wide stats the merge tool reconciles flight bytes against.
    pub fn write_traces(&self, dir: &std::path::Path)
                        -> Result<(), RegistryError> {
        for (party, sink) in self.sinks.iter().enumerate() {
            let stats = self.link_stats(party);
            crate::trace::write_party_trace(dir, party, sink, &stats)
                .map_err(|e| RegistryError::Service {
                    model: format!("trace-p{party}"),
                    source: anyhow!("trace export: {e}"),
                })?;
        }
        Ok(())
    }

    /// Per-model serving rollups (party 0's view), in slot order: each
    /// model's online and offline lane traffic, its bank counters (a
    /// quarantined slot reports its last-drained defaults), and its
    /// slot's lifecycle history.
    pub fn rollups(&self) -> Vec<ModelRollup> {
        let stats = self.link_stats(0);
        let inner = recover(self.inner.lock());
        let mut rows: Vec<ModelRollup> = inner.entries.iter()
            .map(|e| ModelRollup {
                name: e.name.clone(),
                slot: e.slot,
                online: stats.chan(ChanId::online(e.slot)),
                offline: stats.chan(ChanId::offline(e.slot)),
                preproc: e.service.as_ref()
                    .map(|s| s.bank_handle(0).metrics())
                    .unwrap_or_default(),
                lifecycle: inner.lifecycle.get(&e.slot).copied()
                    .unwrap_or_default(),
            }).collect();
        rows.sort_by_key(|r| r.slot);
        rows
    }

    /// Stop every live service (slot order, graceful) and return each
    /// model's name with the link-wide stats its party threads observed
    /// at exit.  Every slot is drained even when one fails (a panic in
    /// one model's drain must not detach the others' threads); the
    /// first failure is then reported as `Drain`.
    pub fn shutdown(self)
                    -> Result<Vec<(String, [Stats; 3])>, RegistryError> {
        let mut inner = recover(self.inner.lock());
        inner.entries.sort_by_key(|e| e.slot);
        let mut out = Vec::new();
        let mut first_err = None;
        for e in &inner.entries {
            if let Some(svc) = &e.service {
                match svc.shutdown() {
                    Ok(stats) => out.push((e.name.clone(), stats)),
                    Err(err) if first_err.is_none() => {
                        first_err = Some(RegistryError::Drain {
                            model: e.name.clone(),
                            detail: err.to_string(),
                        });
                    }
                    Err(_) => {}
                }
            }
        }
        match first_err {
            None => Ok(out),
            Some(e) => Err(e),
        }
    }
}

/// One queued request.
struct Pending {
    image: Tensor,
    enqueued: Instant,
    respond: Sender<Response>,
}

/// Reply to a client.
#[derive(Clone, Debug)]
pub struct Response {
    pub logits: Vec<i32>,
    pub pred: usize,
    pub latency: Duration,
}

/// Dynamic batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
    /// Tuple prefetch depth: keep `prefetch * demand(max_batch)` elements
    /// of deterministic bank headroom ahead of the online stream (0
    /// disables the batcher's pump; the service prefill still applies).
    pub prefetch: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(5),
                      prefetch: 2 }
    }
}

/// Request router + dynamic batcher in front of a `Service`.
pub struct Coordinator {
    req_tx: Sender<Pending>,
    batcher: Option<JoinHandle<(Histogram, Throughput)>>,
    bank0: Arc<TupleBank>,
}

impl Coordinator {
    pub fn start(svc: Service, policy: BatchPolicy) -> Coordinator {
        let (req_tx, req_rx) = channel::<Pending>();
        let bank0 = svc.bank_handle(0);
        let prefetch_unit = svc.demand_for(policy.max_batch.max(1));
        if svc.preprocess {
            let bc = bank0.config();
            let max_draw = svc.max_draw_for(policy.max_batch.max(1));
            if max_draw + bc.chunk > bc.capacity {
                eprintln!(
                    "[coordinator] bank capacity {} cannot admit a full \
                     batch's largest MSB draw ({max_draw} elements at \
                     batch {}); such draws will mint inline -- raise \
                     --bank-capacity or match the service max_batch to \
                     the policy", bc.capacity, policy.max_batch);
            }
        }
        let batcher = thread::spawn(move || {
            let mut hist = Histogram::default();
            let mut served = 0u64;
            let t0 = Instant::now();
            loop {
                // block for the first request, then fill the batch up to
                // the deadline
                let first = match req_rx.recv() {
                    Ok(p) => p,
                    Err(_) => break, // all clients gone
                };
                let mut batch = vec![first];
                let deadline = Instant::now() + policy.max_wait;
                while batch.len() < policy.max_batch {
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    match req_rx.recv_timeout(deadline - now) {
                        Ok(p) => batch.push(p),
                        Err(_) => break,
                    }
                }
                // pump the producers *before* the batch: the refill jobs
                // land ahead of the infer job in every party's queue, so
                // minting overlaps this batch's online phase
                if policy.prefetch > 0 {
                    svc.top_up_to(policy.prefetch * prefetch_unit);
                }
                let images: Vec<Tensor> =
                    batch.iter().map(|p| p.image.clone()).collect();
                match svc.infer(images) {
                    Ok(logits) => {
                        for (p, l) in batch.into_iter().zip(logits) {
                            let lat = p.enqueued.elapsed();
                            hist.record(lat);
                            served += 1;
                            let pred = crate::engine::argmax(&l);
                            let _ = p.respond.send(Response {
                                logits: l, pred, latency: lat,
                            });
                        }
                    }
                    Err(e) => {
                        eprintln!("[coordinator] batch failed: {e}");
                    }
                }
            }
            let _ = svc.shutdown();
            (hist, Throughput { requests: served, wall: t0.elapsed() })
        });
        Coordinator { req_tx, batcher: Some(batcher), bank0 }
    }

    /// Submit a request; returns the channel the response arrives on.
    pub fn submit(&self, image: Tensor) -> Receiver<Response> {
        let (tx, rx) = channel();
        let _ = self.req_tx.send(Pending {
            image,
            enqueued: Instant::now(),
            respond: tx,
        });
        rx
    }

    /// Party 0's offline-preprocessing counters (identical trajectories
    /// on all parties): the request path is clean iff
    /// `underflow_calls == 0`.
    pub fn preproc_metrics(&self) -> PreprocMetrics {
        self.bank0.metrics()
    }

    /// Drop the ingress and wait for the batcher to drain; returns the
    /// latency histogram and throughput aggregate.  A panicked (or
    /// already-reaped) batcher yields empty aggregates instead of
    /// propagating the panic through the drain path.
    pub fn finish(mut self) -> (Histogram, Throughput) {
        drop(self.req_tx);
        match self.batcher.take() {
            Some(h) => h.join()
                .unwrap_or((Histogram::default(), Throughput::default())),
            None => (Histogram::default(), Throughput::default()),
        }
    }
}

/// Shared-handle client helper for multi-threaded load generators.
pub type SharedCoordinator = Arc<Mutex<Coordinator>>;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::threeparty::every_op_model;
    use crate::testutil::Rng;

    #[test]
    fn batch_policy_defaults_sane() {
        let p = BatchPolicy::default();
        assert!(p.max_batch >= 1);
        assert!(p.max_wait > Duration::ZERO);
        assert!(p.prefetch >= 1);
    }

    #[test]
    fn service_prefills_bank_to_high_watermark() {
        let model = Arc::new(every_op_model());
        let cfg = SessionConfig::new("artifacts/hlo");
        let svc = Service::start(model, cfg).expect("setup");
        let high = svc.bank_cfg.high;
        for p in 0..3 {
            let b = svc.bank_handle(p);
            assert!(b.level() >= high,
                    "party {p} bank at {} < high watermark {high}",
                    b.level());
            assert_eq!(b.metrics().underflow_calls, 0);
        }
        let _ = svc.shutdown();
    }

    #[test]
    fn warm_bank_serves_with_zero_request_path_generation() {
        // the PR acceptance gate: Coordinator::submit -> response with a
        // warm TupleBank performs zero synchronous mints on the request
        // path, asserted via the underflow metrics counter
        let model = Arc::new(every_op_model());
        let cfg = SessionConfig::new("artifacts/hlo");
        let svc = Service::start(model, cfg).expect("setup");
        let coord = Coordinator::start(svc, BatchPolicy::default());
        let mut rng = Rng::new(11);
        let rxs: Vec<_> = (0..6).map(|_| {
            coord.submit(rng.tensor_small(&[1, 36], 15))
        }).collect();
        for rx in rxs {
            let resp = rx.recv().expect("response");
            assert_eq!(resp.logits.len(), 3);
        }
        let m = coord.preproc_metrics();
        let (hist, thr) = coord.finish();
        assert_eq!(thr.requests, 6);
        assert_eq!(hist.count(), 6);
        assert_eq!(m.underflow_calls, 0,
                   "request path minted inline: {m:?}");
        assert_eq!(m.fallback_elems, 0);
        assert!(m.drawn > 0, "bank never drawn from: {m:?}");
    }

    #[test]
    fn dropped_party_surfaces_as_infer_error_not_hang() {
        // Retire one party mid-session: the hardened send path turns the
        // survivors' messages to the dead peer into WireError::Closed, the
        // party threads break out of their job loops, and the Service
        // surfaces an Err to the caller instead of panicking or hanging.
        let model = Arc::new(every_op_model());
        let cfg = SessionConfig::new("artifacts/hlo");
        let svc = Service::start(model, cfg).expect("setup with all parties");
        // kill party 2's thread abruptly: it exits without draining,
        // dropping its Comm endpoints
        svc.inject_fault(2);
        let mut rng = Rng::new(3);
        let input = rng.tensor_small(&[1, 36], 15);
        let got = svc.infer(vec![input]);
        assert!(got.is_err(), "inference with a dead peer must error");
        // the remaining party threads retired: abort joins them (the
        // graceful path is not guaranteed after a fault)
        let _ = svc.abort();
    }

    #[test]
    fn shutdown_is_idempotent_and_typed() {
        let model = Arc::new(every_op_model());
        let cfg = SessionConfig::new("artifacts/hlo");
        let svc = Service::start(model, cfg).expect("setup");
        let first = svc.shutdown().expect("clean drain");
        let second = svc.shutdown().expect("cached drain");
        assert_eq!(first[0].bytes_sent, second[0].bytes_sent);
        // abort after shutdown is a no-op returning the same stats
        let third = svc.abort().expect("cached drain");
        assert_eq!(first[0].bytes_sent, third[0].bytes_sent);
    }

    #[test]
    fn poisoned_scheduler_lock_does_not_panic_the_request_path() {
        let model = Arc::new(every_op_model());
        let cfg = SessionConfig::new("artifacts/hlo");
        let svc = Service::start(model, cfg).expect("setup");
        // inject: a thread panics while holding the broadcast lock
        let res = thread::scope(|s| {
            s.spawn(|| {
                let _g = svc.sched.lock().unwrap();
                panic!("injected poison");
            }).join()
        });
        assert!(res.is_err());
        assert!(svc.sched.is_poisoned(), "injection failed");
        // the request path recovers the guard instead of cascading the
        // panic: the guarded state was never left torn, so serving
        // continues
        let mut rng = Rng::new(21);
        let logits = svc.infer(vec![rng.tensor_small(&[1, 36], 15)])
            .expect("poisoned sched lock must not fail serving");
        assert_eq!(logits[0].len(), 3);
        let _ = svc.shutdown();
    }

    #[test]
    fn poisoned_registry_lock_keeps_lifecycle_typed() {
        let model = Arc::new(every_op_model());
        let cfg = SessionConfig::new("artifacts/hlo");
        let reg = ModelRegistry::start(
            vec![ModelSpec::new("a", Arc::clone(&model))], &cfg)
            .expect("registry up");
        let res = thread::scope(|s| {
            s.spawn(|| {
                let _g = reg.inner.lock().unwrap();
                panic!("injected poison");
            }).join()
        });
        assert!(res.is_err());
        assert!(reg.inner.is_poisoned(), "injection failed");
        // lookups, routing, and lifecycle transitions stay panic-free
        // and typed after the poison
        assert_eq!(reg.state("a").unwrap(), SlotState::Serving);
        assert!(matches!(reg.state("nope").unwrap_err(),
                         RegistryError::UnknownModel(_)));
        let mut rng = Rng::new(23);
        let logits = reg.infer("a", vec![rng.tensor_small(&[1, 36], 15)])
            .expect("serving continues after poison");
        assert_eq!(logits.len(), 1);
        let _ = reg.shutdown();
    }

    // ---- model registry -------------------------------------------------

    #[test]
    fn registry_rejects_bad_spec_lists_with_typed_errors() {
        let cfg = SessionConfig::new("artifacts/hlo");
        // empty list
        let err = ModelRegistry::start(vec![], &cfg).err().unwrap();
        assert!(matches!(err, RegistryError::Empty), "{err:?}");
        // duplicate names (satellite: typed, inspectable error naming
        // the offending model)
        let model = Arc::new(every_op_model());
        let specs = vec![
            ModelSpec::new("everyop", Arc::clone(&model)),
            ModelSpec::new("everyop", Arc::clone(&model)),
        ];
        let err = ModelRegistry::start(specs, &cfg).err().unwrap();
        match &err {
            RegistryError::DuplicateModel(n) => assert_eq!(n, "everyop"),
            other => panic!("expected DuplicateModel, got {other:?}"),
        }
        assert!(err.to_string().contains("everyop"), "{err}");
        // the typed-error check spawns nothing: no links were built, so
        // the error arrives without any party/producer threads to reap
    }

    #[test]
    fn registry_routes_by_name_and_rejects_unknown_models() {
        let model = Arc::new(every_op_model());
        let cfg = SessionConfig::new("artifacts/hlo");
        let reg = ModelRegistry::start(
            vec![ModelSpec::new("a", Arc::clone(&model))], &cfg)
            .expect("registry up");
        assert_eq!(reg.names(), vec!["a"]);
        assert_eq!(reg.service("a").unwrap().slot, 0);
        let err = reg.service("nope").err().unwrap();
        assert!(matches!(err, RegistryError::UnknownModel(_)), "{err:?}");
        let mut rng = Rng::new(17);
        let err = reg.infer("nope", vec![rng.tensor_small(&[1, 36], 15)])
            .err().unwrap();
        assert!(matches!(err, RegistryError::UnknownModel(_)), "{err:?}");
        let logits = reg.infer("a", vec![rng.tensor_small(&[1, 36], 15)])
            .expect("routed batch");
        assert_eq!(logits.len(), 1);
        assert_eq!(logits[0].len(), 3);
        let _ = reg.shutdown();
    }
}
