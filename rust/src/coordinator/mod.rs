//! Serving coordinator (L3): persistent three-party session + request
//! router + dynamic batcher + metrics, in the style of a vLLM router.
//!
//! A `Service` pins the three party threads for the lifetime of a model:
//! the model is secret-shared once, PJRT executables are warmed up once,
//! and every subsequent batch pays only the online protocol cost.  The
//! `Coordinator` in front owns the request queue and forms batches by
//! size/deadline -- batching in 3PC amortizes *rounds*, which is the
//! dominant WAN cost (the protocols are batched across samples inside the
//! engine, so a batch of 8 pays the same round count as a batch of 1).
//!
//! **Offline/online split.**  Each party thread spawns a background tuple
//! producer that mints MSB correlated material over the tagged
//! per-model offline transport lane into a watermark-managed
//! `offline::TupleBank`.  `Service::start` pre-fills every bank to the
//! high watermark before serving; the refill pump (`top_up_to`, driven by
//! the batcher's `BatchPolicy::prefetch` knob) broadcasts chunk-sized
//! refill jobs whenever deterministic headroom drops below the low
//! watermark.  Refill and infer jobs share one broadcast lock, so all
//! three parties observe the identical command order and agree on every
//! pooled-vs-fallback decision -- with a warm bank, a request performs
//! *zero* synchronous mints on its critical path (asserted by
//! `PreprocMetrics::underflow_calls == 0`).
//!
//! **Multi-model serving.**  A [`ModelRegistry`] hosts N `Service`s over
//! *one* process's three links: every model gets a channel-id slot
//! (`ChanId::online(slot)` / `ChanId::offline(slot)`), its own
//! model-scoped PRF seed domain (`engine::session::model_seed`, so no
//! two lanes ever share counters), its own auto-sized `TupleBank`, and
//! its own producer lane in the background minting pool.  Lanes demux
//! per frame at the transport layer, so interleaved batches for
//! different models compute exactly what their single-model sessions
//! would -- bit-identically (asserted by `rust/tests/multimodel.rs`).
//! See DESIGN.md §Multi-model multiplexing.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::engine::session::{model_seed, SessionConfig};
use crate::engine::{infer_batch_pooled, msb_demand_for, share_model,
                    SharedModel};
use crate::metrics::{Histogram, ModelRollup, PreprocMetrics, Throughput};
use crate::nn::Model;
use crate::offline::{offline_seeds, run_producer, BankConfig, TupleBank,
                     TupleSource};
use crate::prf::PartySeeds;
use crate::protocols::Ctx;
use crate::ring::Tensor;
use crate::runtime::make_backend;
use crate::transport::{local_trio, ChanId, Comm, Stats};

enum Job {
    Infer { inputs: Vec<Tensor>, batch: usize },
    /// Mint `n` more tuple elements in the background (forwarded to the
    /// party's producer thread; the bank is credited in broadcast order).
    Refill(usize),
    Shutdown,
}

/// Broadcast state: the three job senders plus the pump's dispatch
/// accounting.  One lock for both, so every party sees refill and infer
/// jobs in the same order (the determinism the bank's credit accounting
/// relies on).
struct Sched {
    txs: Vec<Sender<Job>>,
    /// Elements promised by dispatched refill jobs.
    dispatched: usize,
}

/// A persistent three-party inference service for one model: pinned
/// party threads, a shared secret-shared model, per-party `TupleBank`s
/// kept warm by background producers, and a broadcast job queue whose
/// order every party observes identically (the determinism the bank's
/// credit accounting relies on).
///
/// A service either owns its own links (`Service::start`) or shares one
/// process's links with other models (`Service::start_on`, used by
/// [`ModelRegistry`]): its online protocol traffic runs on
/// `ChanId::online(slot)`, its producers on `ChanId::offline(slot)`,
/// and its PRF streams live in the model-scoped seed domain
/// `model_seed(session_seed, slot)`.
pub struct Service {
    sched: Mutex<Sched>,
    logits_rx: Receiver<Result<Vec<Vec<i32>>>>,
    handles: Vec<JoinHandle<Stats>>,
    banks: Vec<Arc<TupleBank>>,
    bank_cfg: BankConfig,
    preprocess: bool,
    model: Arc<Model>,
    /// The channel-id model slot this service's lanes are bound to.
    pub slot: u8,
    pub model_name: String,
    pub setup_time: Duration,
}

impl Service {
    /// Spin up the party threads over fresh in-process links, share the
    /// model, warm the PJRT caches, and pre-fill the tuple banks to the
    /// high watermark.
    pub fn start(model: Arc<Model>, cfg: SessionConfig) -> Result<Service> {
        Service::start_at(model, cfg, 0)
    }

    /// `start` pinned to channel-id model slot `slot` (fresh links).
    /// The single-model reference arm for multi-model tests: a service
    /// started at slot s standalone runs the identical seed domain and
    /// lane ids as slot s of a registry, so logits are bit-comparable.
    pub fn start_at(model: Arc<Model>, cfg: SessionConfig, slot: u8)
                    -> Result<Service> {
        let comms = local_trio(cfg.net);
        Service::start_on(model, cfg, comms, slot)
    }

    /// Spin up this model's party threads over *externally provided*
    /// links -- the multi-model entry point.  `comms` are the three
    /// parties' handles of one shared link trio (any lane binding); the
    /// service derives -- and thereby registers, before any of its
    /// threads spawn -- its own `ChanId::online(slot)` /
    /// `ChanId::offline(slot)` lane pair, so its frames never
    /// interleave with another model's.  All PRF streams (online and
    /// producer) are drawn from the model-scoped seed domain
    /// `model_seed(cfg.session_seed, slot)`.
    pub fn start_on(model: Arc<Model>, cfg: SessionConfig,
                    comms: [Comm; 3], slot: u8) -> Result<Service> {
        let bank_cfg = cfg.bank.unwrap_or_else(|| {
            BankConfig::auto(msb_demand_for(&model, cfg.max_batch.max(1)))
        });
        bank_cfg.validate().map_err(|e| anyhow!("bank config: {e}"))?;
        let seed = model_seed(cfg.session_seed, slot);
        // derive (= register) the lanes on every party BEFORE spawning
        // anything: a peer's first frame for this slot must find the id
        // registered, or the demux would reject it as malformed.  The
        // offline lane is derived only when producers will actually
        // read it -- registering a never-read id would hand a malicious
        // peer an unbounded parking queue instead of a Malformed error.
        let lanes: Vec<(Comm, Option<Comm>)> = comms.into_iter().map(|c| {
            let on = c.channel(ChanId::online(slot));
            let off = cfg.opts.preprocess
                .then(|| on.channel(ChanId::offline(slot)));
            (on, off)
        }).collect();
        let banks: Vec<Arc<TupleBank>> =
            (0..3).map(|_| Arc::new(TupleBank::new(bank_cfg))).collect();
        let (logits_tx, logits_rx) = channel();
        let mut job_txs = Vec::new();
        let mut handles = Vec::new();
        let (ready_tx, ready_rx) = channel();
        for ((comm, off_comm), bank) in
            lanes.into_iter().zip(banks.iter().cloned()) {
            let model = Arc::clone(&model);
            let cfg = cfg.clone();
            let logits_tx = logits_tx.clone();
            let ready_tx = ready_tx.clone();
            let (jtx, jrx) = channel::<Job>();
            job_txs.push(jtx);
            handles.push(thread::spawn(move || -> Stats {
                let seeds = PartySeeds::setup(seed, comm.id);
                let ctx = Ctx::with_cfg(&comm, &seeds, cfg.proto);
                // build the backend, warming the PJRT executable cache
                // before the first request (warmup is a no-op for native)
                let backend: Box<dyn crate::protocols::linear::LinearBackend> =
                    match make_backend(cfg.backend, &cfg.hlo_dir) {
                        Ok(b) => b,
                        Err(e) => {
                            let _ = ready_tx.send(
                                Err(anyhow!("backend: {e}")));
                            return comm.stats();
                        }
                    };
                backend.warmup(&crate::engine::hlo_keys(&model));
                let shared: SharedModel =
                    match share_model(&ctx, &model, comm.id == 1) {
                        Ok(s) => s,
                        Err(e) => {
                            let _ = ready_tx.send(Err(anyhow!("share: {e}")));
                            return comm.stats();
                        }
                    };
                // background tuple producer: its own thread, its own PRF
                // domain, this model's offline lane of the same links.
                // Refill jobs are forwarded to it so minting overlaps
                // with online inference instead of riding the request.
                let (prod_tx, prod_rx) = channel::<usize>();
                let producer = off_comm.map(|off_comm| {
                    let off_seeds = offline_seeds(seed, comm.id);
                    let proto = cfg.proto;
                    let pbank = Arc::clone(&bank);
                    thread::spawn(move || {
                        let octx = Ctx::with_cfg(&off_comm, &off_seeds,
                                                 proto);
                        if let Err(e) = run_producer(&octx, pbank.as_ref(),
                                                     prod_rx) {
                            eprintln!("[service {}] offline producer \
                                       failed: {e}", off_comm.id);
                            pbank.close();
                        }
                    })
                });
                let _ = ready_tx.send(Ok(comm.id));
                while let Ok(job) = jrx.recv() {
                    match job {
                        Job::Shutdown => break,
                        Job::Refill(n) => {
                            // credit in broadcast order (deterministic
                            // across parties), then hand the mint to the
                            // background producer
                            bank.credit(n);
                            let _ = prod_tx.send(n);
                        }
                        Job::Infer { inputs, batch } => {
                            let src = if cfg.opts.preprocess {
                                TupleSource::Bank(bank.as_ref())
                            } else {
                                TupleSource::Inline
                            };
                            let r = infer_batch_pooled(
                                &ctx, &shared, backend.as_ref(), cfg.opts,
                                &inputs, batch, &src);
                            let failed = r.is_err();
                            if comm.id == 0 {
                                let _ = logits_tx.send(
                                    r.map(|o| o.logits)
                                     .map_err(|e| anyhow!("{e}")));
                            } else if let Err(e) = &r {
                                eprintln!("[service {}] inference failed: \
                                           {e}", comm.id);
                            }
                            if failed {
                                // a failed protocol leaves the trio
                                // desynchronized; retire this party --
                                // dropping its Comm unblocks any peer
                                // stuck in recv with WireError::Closed
                                // instead of hanging the Service
                                break;
                            }
                        }
                    }
                }
                // graceful drain: wake any backpressured delivery, let
                // the producer finish its queued chunks (identical on
                // all parties, so the interactive mints complete), and
                // join it before this party's links drop
                bank.close();
                drop(prod_tx);
                if let Some(h) = producer {
                    let _ = h.join();
                }
                comm.stats()
            }));
        }
        let t0 = Instant::now();
        for _ in 0..3 {
            ready_rx.recv().map_err(|_| anyhow!("party died in setup"))??;
        }
        let svc = Service {
            sched: Mutex::new(Sched { txs: job_txs, dispatched: 0 }),
            logits_rx,
            handles,
            banks,
            bank_cfg,
            preprocess: cfg.opts.preprocess,
            slot,
            model_name: model.name.clone(),
            model,
            setup_time: t0.elapsed(),
        };
        // offline prefill: reach the high watermark before serving, so
        // the first request already runs the 2-round online MSB
        if svc.preprocess {
            svc.top_up_to(svc.bank_cfg.high);
            for b in &svc.banks {
                b.wait_level(svc.bank_cfg.high)
                    .map_err(|e| anyhow!("offline prefill: {e}"))?;
            }
        }
        Ok(svc)
    }

    /// MSB tuple demand of one `batch`-sized request (public manifest
    /// arithmetic; the pump's refill unit).
    pub fn demand_for(&self, batch: usize) -> usize {
        msb_demand_for(&self.model, batch)
    }

    /// Largest single MSB draw a `batch`-sized request makes.  Draws
    /// above `capacity - chunk` always fall back (deadlock freedom), so
    /// the batcher checks this against the bank at startup.
    pub fn max_draw_for(&self, batch: usize) -> usize {
        crate::engine::msb_sizes_of(&self.model.ops, self.model.input,
                                    batch)
            .into_iter().max().unwrap_or(0)
    }

    /// Party `i`'s tuple bank (observability: levels and
    /// `PreprocMetrics`; all parties' banks evolve identically).
    pub fn bank_handle(&self, party: usize) -> Arc<TupleBank> {
        Arc::clone(&self.banks[party])
    }

    /// The watermark pump: when deterministic headroom (dispatched minus
    /// reserved elements) is below the low watermark or below
    /// `target_elems`, broadcast chunk-sized refill jobs until it reaches
    /// `max(target_elems, high)` (clamped to capacity).  Deterministic:
    /// refills share the infer broadcast lock, so every party folds them
    /// into its credit accounting at the same point of the job order.
    pub fn top_up_to(&self, target_elems: usize) {
        if !self.preprocess {
            return;
        }
        let goal = target_elems
            .max(self.bank_cfg.high)
            .min(self.bank_cfg.capacity);
        let mut sched = self.sched.lock().unwrap();
        let reserved = self.banks[0].reserved_elems();
        let mut avail = sched.dispatched.saturating_sub(reserved);
        if avail >= self.bank_cfg.low && avail >= target_elems {
            return;
        }
        while avail < goal {
            for tx in &sched.txs {
                let _ = tx.send(Job::Refill(self.bank_cfg.chunk));
            }
            sched.dispatched += self.bank_cfg.chunk;
            avail += self.bank_cfg.chunk;
        }
    }

    /// Run one batch through the session (blocking).  Over a service's
    /// own links a failed protocol surfaces as `Err` (the failing
    /// party's retirement drops the link cores and `Closed` unblocks
    /// its peers); in a registry the shared links outlive one lane's
    /// threads, so a *partial* lane failure can leave this call
    /// blocked -- see DESIGN.md §Multi-model multiplexing, failure
    /// isolation.
    pub fn infer(&self, inputs: Vec<Tensor>) -> Result<Vec<Vec<i32>>> {
        let batch = inputs.len();
        // keep the bank at its own watermarks even without a Coordinator
        // in front: the refill jobs land ahead of this infer in every
        // party's queue (same broadcast lock), so the producers overlap
        // this batch instead of draining the prefill dry
        self.top_up_to(0);
        {
            let sched = self.sched.lock().unwrap();
            for (id, tx) in sched.txs.iter().enumerate() {
                let job = Job::Infer {
                    inputs: if id == 0 { inputs.clone() } else { vec![] },
                    batch,
                };
                tx.send(job).map_err(|_| anyhow!("party {id} gone"))?;
            }
        }
        self.logits_rx.recv().map_err(|_| anyhow!("no response"))?
    }

    /// Stop the party threads and collect their comm stats.  In a
    /// registry, the returned stats are *link-wide* (the cores are
    /// shared); use `Stats::chan`/`Stats::model` with this service's
    /// `slot` for its own rows.
    pub fn shutdown(self) -> [Stats; 3] {
        {
            let sched = self.sched.lock().unwrap();
            for tx in &sched.txs {
                let _ = tx.send(Job::Shutdown);
            }
        }
        let stats: Vec<Stats> = self.handles.into_iter()
            .map(|h| h.join().unwrap_or_default()).collect();
        stats.try_into().expect("three party threads")
    }
}

/// One model entry for [`ModelRegistry::start`]: a unique name (the
/// routing key), the manifest-loaded model, and an optional per-model
/// bank override (`None` auto-scales via `BankConfig::auto` to the
/// model's own demand at the session's `max_batch`).
pub struct ModelSpec {
    pub name: String,
    pub model: Arc<Model>,
    pub bank: Option<BankConfig>,
}

impl ModelSpec {
    pub fn new(name: impl Into<String>, model: Arc<Model>) -> ModelSpec {
        ModelSpec { name: name.into(), model, bank: None }
    }
}

/// Typed registry failure: what was wrong with a spec list or a lookup,
/// inspectable by callers (the CLI maps these to flag hints).
#[derive(Debug)]
pub enum RegistryError {
    /// `start` needs at least one model spec.
    Empty,
    /// Two specs share a name; the name is the routing key.
    DuplicateModel(String),
    /// More models than the channel-id space has slots.
    TooManyModels { count: usize, max: usize },
    /// `infer`/`service` lookup for a name nobody registered.
    UnknownModel(String),
    /// A model's `Service` failed to start or serve.
    Service { model: String, source: anyhow::Error },
}

impl std::fmt::Display for RegistryError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RegistryError::Empty =>
                write!(f, "registry needs at least one model spec"),
            RegistryError::DuplicateModel(n) =>
                write!(f, "duplicate model name '{n}': registry names \
                           are routing keys and must be unique"),
            RegistryError::TooManyModels { count, max } =>
                write!(f, "{count} models exceed the {max}-slot channel \
                           id space"),
            RegistryError::UnknownModel(n) =>
                write!(f, "no model named '{n}' in the registry"),
            RegistryError::Service { model, source } =>
                write!(f, "model '{model}': {source}"),
        }
    }
}

impl std::error::Error for RegistryError {}

/// N per-model [`Service`]s multiplexed over *one* process's three
/// links: the multi-model serving front.  Each model slot gets its own
/// channel-id lane pair, PRF seed domain, `TupleBank`, and producer
/// lane; requests route by model name.  Slots are assigned in spec
/// order, so a given spec list is reproducible run-to-run (and against
/// `Service::start_at` reference arms).
pub struct ModelRegistry {
    links: [Comm; 3],
    entries: Vec<(String, Service)>,
}

impl ModelRegistry {
    /// Bring up every model's service over one fresh link trio,
    /// sequentially (model sharing and bank prefill are interactive;
    /// one model's setup completes before the next begins).  Spec
    /// validation -- non-empty, unique names, at most
    /// `ChanId::MAX_MODELS` -- happens before any thread spawns.
    pub fn start(specs: Vec<ModelSpec>, cfg: &SessionConfig)
                 -> Result<ModelRegistry, RegistryError> {
        if specs.is_empty() {
            return Err(RegistryError::Empty);
        }
        if specs.len() > ChanId::MAX_MODELS {
            return Err(RegistryError::TooManyModels {
                count: specs.len(),
                max: ChanId::MAX_MODELS,
            });
        }
        let mut seen = std::collections::BTreeSet::new();
        for spec in &specs {
            if !seen.insert(spec.name.clone()) {
                return Err(RegistryError::DuplicateModel(
                    spec.name.clone()));
            }
        }
        let links = local_trio(cfg.net);
        let mut entries = Vec::with_capacity(specs.len());
        for (slot, spec) in specs.into_iter().enumerate() {
            let mut mcfg = cfg.clone();
            mcfg.bank = spec.bank.or(cfg.bank);
            let comms =
                [links[0].clone(), links[1].clone(), links[2].clone()];
            let svc = Service::start_on(spec.model, mcfg, comms,
                                        slot as u8)
                .map_err(|e| RegistryError::Service {
                    model: spec.name.clone(),
                    source: e,
                })?;
            entries.push((spec.name, svc));
        }
        Ok(ModelRegistry { links, entries })
    }

    /// Registered model names, in slot order.
    pub fn names(&self) -> Vec<&str> {
        self.entries.iter().map(|(n, _)| n.as_str()).collect()
    }

    /// The service bound to `name`.
    pub fn service(&self, name: &str) -> Result<&Service, RegistryError> {
        self.entries.iter()
            .find(|(n, _)| n == name)
            .map(|(_, s)| s)
            .ok_or_else(|| RegistryError::UnknownModel(name.to_string()))
    }

    /// Route one batch to `name`'s service (blocking).
    pub fn infer(&self, name: &str, inputs: Vec<Tensor>)
                 -> Result<Vec<Vec<i32>>, RegistryError> {
        let svc = self.service(name)?;
        svc.infer(inputs).map_err(|e| RegistryError::Service {
            model: name.to_string(),
            source: e,
        })
    }

    /// Party `party`'s link-wide comm stats (totals plus every model
    /// lane's `ChanStats` row; rows sum to the totals).
    pub fn link_stats(&self, party: usize) -> Stats {
        self.links[party].stats()
    }

    /// Per-model serving rollups (party 0's view): each model's online
    /// and offline lane traffic plus its bank counters.
    pub fn rollups(&self) -> Vec<ModelRollup> {
        let stats = self.link_stats(0);
        self.entries.iter().map(|(name, svc)| ModelRollup {
            name: name.clone(),
            slot: svc.slot,
            online: stats.chan(ChanId::online(svc.slot)),
            offline: stats.chan(ChanId::offline(svc.slot)),
            preproc: svc.bank_handle(0).metrics(),
        }).collect()
    }

    /// Stop every service (slot order) and return each model's name
    /// with the link-wide stats its party threads observed at exit.
    pub fn shutdown(self) -> Vec<(String, [Stats; 3])> {
        self.entries.into_iter()
            .map(|(n, s)| (n, s.shutdown()))
            .collect()
    }
}

/// One queued request.
struct Pending {
    image: Tensor,
    enqueued: Instant,
    respond: Sender<Response>,
}

/// Reply to a client.
#[derive(Clone, Debug)]
pub struct Response {
    pub logits: Vec<i32>,
    pub pred: usize,
    pub latency: Duration,
}

/// Dynamic batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
    /// Tuple prefetch depth: keep `prefetch * demand(max_batch)` elements
    /// of deterministic bank headroom ahead of the online stream (0
    /// disables the batcher's pump; the service prefill still applies).
    pub prefetch: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(5),
                      prefetch: 2 }
    }
}

/// Request router + dynamic batcher in front of a `Service`.
pub struct Coordinator {
    req_tx: Sender<Pending>,
    batcher: Option<JoinHandle<(Histogram, Throughput)>>,
    bank0: Arc<TupleBank>,
}

impl Coordinator {
    pub fn start(svc: Service, policy: BatchPolicy) -> Coordinator {
        let (req_tx, req_rx) = channel::<Pending>();
        let bank0 = svc.bank_handle(0);
        let prefetch_unit = svc.demand_for(policy.max_batch.max(1));
        if svc.preprocess {
            let bc = bank0.config();
            let max_draw = svc.max_draw_for(policy.max_batch.max(1));
            if max_draw + bc.chunk > bc.capacity {
                eprintln!(
                    "[coordinator] bank capacity {} cannot admit a full \
                     batch's largest MSB draw ({max_draw} elements at \
                     batch {}); such draws will mint inline -- raise \
                     --bank-capacity or match the service max_batch to \
                     the policy", bc.capacity, policy.max_batch);
            }
        }
        let batcher = thread::spawn(move || {
            let mut hist = Histogram::default();
            let mut served = 0u64;
            let t0 = Instant::now();
            loop {
                // block for the first request, then fill the batch up to
                // the deadline
                let first = match req_rx.recv() {
                    Ok(p) => p,
                    Err(_) => break, // all clients gone
                };
                let mut batch = vec![first];
                let deadline = Instant::now() + policy.max_wait;
                while batch.len() < policy.max_batch {
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    match req_rx.recv_timeout(deadline - now) {
                        Ok(p) => batch.push(p),
                        Err(_) => break,
                    }
                }
                // pump the producers *before* the batch: the refill jobs
                // land ahead of the infer job in every party's queue, so
                // minting overlaps this batch's online phase
                if policy.prefetch > 0 {
                    svc.top_up_to(policy.prefetch * prefetch_unit);
                }
                let images: Vec<Tensor> =
                    batch.iter().map(|p| p.image.clone()).collect();
                match svc.infer(images) {
                    Ok(logits) => {
                        for (p, l) in batch.into_iter().zip(logits) {
                            let lat = p.enqueued.elapsed();
                            hist.record(lat);
                            served += 1;
                            let pred = crate::engine::argmax(&l);
                            let _ = p.respond.send(Response {
                                logits: l, pred, latency: lat,
                            });
                        }
                    }
                    Err(e) => {
                        eprintln!("[coordinator] batch failed: {e}");
                    }
                }
            }
            let _ = svc.shutdown();
            (hist, Throughput { requests: served, wall: t0.elapsed() })
        });
        Coordinator { req_tx, batcher: Some(batcher), bank0 }
    }

    /// Submit a request; returns the channel the response arrives on.
    pub fn submit(&self, image: Tensor) -> Receiver<Response> {
        let (tx, rx) = channel();
        let _ = self.req_tx.send(Pending {
            image,
            enqueued: Instant::now(),
            respond: tx,
        });
        rx
    }

    /// Party 0's offline-preprocessing counters (identical trajectories
    /// on all parties): the request path is clean iff
    /// `underflow_calls == 0`.
    pub fn preproc_metrics(&self) -> PreprocMetrics {
        self.bank0.metrics()
    }

    /// Drop the ingress and wait for the batcher to drain; returns the
    /// latency histogram and throughput aggregate.
    pub fn finish(mut self) -> (Histogram, Throughput) {
        drop(self.req_tx);
        self.batcher.take().unwrap().join()
            .unwrap_or((Histogram::default(), Throughput::default()))
    }
}

/// Shared-handle client helper for multi-threaded load generators.
pub type SharedCoordinator = Arc<Mutex<Coordinator>>;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::threeparty::every_op_model;
    use crate::testutil::Rng;

    #[test]
    fn batch_policy_defaults_sane() {
        let p = BatchPolicy::default();
        assert!(p.max_batch >= 1);
        assert!(p.max_wait > Duration::ZERO);
        assert!(p.prefetch >= 1);
    }

    #[test]
    fn service_prefills_bank_to_high_watermark() {
        let model = Arc::new(every_op_model());
        let cfg = SessionConfig::new("artifacts/hlo");
        let svc = Service::start(model, cfg).expect("setup");
        let high = svc.bank_cfg.high;
        for p in 0..3 {
            let b = svc.bank_handle(p);
            assert!(b.level() >= high,
                    "party {p} bank at {} < high watermark {high}",
                    b.level());
            assert_eq!(b.metrics().underflow_calls, 0);
        }
        let _ = svc.shutdown();
    }

    #[test]
    fn warm_bank_serves_with_zero_request_path_generation() {
        // the PR acceptance gate: Coordinator::submit -> response with a
        // warm TupleBank performs zero synchronous mints on the request
        // path, asserted via the underflow metrics counter
        let model = Arc::new(every_op_model());
        let cfg = SessionConfig::new("artifacts/hlo");
        let svc = Service::start(model, cfg).expect("setup");
        let coord = Coordinator::start(svc, BatchPolicy::default());
        let mut rng = Rng::new(11);
        let rxs: Vec<_> = (0..6).map(|_| {
            coord.submit(rng.tensor_small(&[1, 36], 15))
        }).collect();
        for rx in rxs {
            let resp = rx.recv().expect("response");
            assert_eq!(resp.logits.len(), 3);
        }
        let m = coord.preproc_metrics();
        let (hist, thr) = coord.finish();
        assert_eq!(thr.requests, 6);
        assert_eq!(hist.count(), 6);
        assert_eq!(m.underflow_calls, 0,
                   "request path minted inline: {m:?}");
        assert_eq!(m.fallback_elems, 0);
        assert!(m.drawn > 0, "bank never drawn from: {m:?}");
    }

    #[test]
    fn dropped_party_surfaces_as_infer_error_not_hang() {
        // Retire one party mid-session: the hardened send path turns the
        // survivors' messages to the dead peer into WireError::Closed, the
        // party threads break out of their job loops, and the Service
        // surfaces an Err to the caller instead of panicking or hanging.
        let model = Arc::new(every_op_model());
        let cfg = SessionConfig::new("artifacts/hlo");
        let svc = Service::start(model, cfg).expect("setup with all parties");
        // kill party 2's thread: it drains its job queue, hits Shutdown,
        // and drops its Comm endpoints
        svc.sched.lock().unwrap().txs[2].send(Job::Shutdown).unwrap();
        let mut rng = Rng::new(3);
        let input = rng.tensor_small(&[1, 36], 15);
        let got = svc.infer(vec![input]);
        assert!(got.is_err(), "inference with a dead peer must error");
        // the remaining party threads retired cleanly: shutdown joins
        let _ = svc.shutdown();
    }

    // ---- model registry -------------------------------------------------

    #[test]
    fn registry_rejects_bad_spec_lists_with_typed_errors() {
        let cfg = SessionConfig::new("artifacts/hlo");
        // empty list
        let err = ModelRegistry::start(vec![], &cfg).err().unwrap();
        assert!(matches!(err, RegistryError::Empty), "{err:?}");
        // duplicate names (satellite: typed, inspectable error naming
        // the offending model)
        let model = Arc::new(every_op_model());
        let specs = vec![
            ModelSpec::new("everyop", Arc::clone(&model)),
            ModelSpec::new("everyop", Arc::clone(&model)),
        ];
        let err = ModelRegistry::start(specs, &cfg).err().unwrap();
        match &err {
            RegistryError::DuplicateModel(n) => assert_eq!(n, "everyop"),
            other => panic!("expected DuplicateModel, got {other:?}"),
        }
        assert!(err.to_string().contains("everyop"), "{err}");
        // the typed-error check spawns nothing: no links were built, so
        // the error arrives without any party/producer threads to reap
    }

    #[test]
    fn registry_routes_by_name_and_rejects_unknown_models() {
        let model = Arc::new(every_op_model());
        let cfg = SessionConfig::new("artifacts/hlo");
        let reg = ModelRegistry::start(
            vec![ModelSpec::new("a", Arc::clone(&model))], &cfg)
            .expect("registry up");
        assert_eq!(reg.names(), vec!["a"]);
        assert_eq!(reg.service("a").unwrap().slot, 0);
        let err = reg.service("nope").err().unwrap();
        assert!(matches!(err, RegistryError::UnknownModel(_)), "{err:?}");
        let mut rng = Rng::new(17);
        let err = reg.infer("nope", vec![rng.tensor_small(&[1, 36], 15)])
            .err().unwrap();
        assert!(matches!(err, RegistryError::UnknownModel(_)), "{err:?}");
        let logits = reg.infer("a", vec![rng.tensor_small(&[1, 36], 15)])
            .expect("routed batch");
        assert_eq!(logits.len(), 1);
        assert_eq!(logits[0].len(), 3);
        reg.shutdown();
    }
}
