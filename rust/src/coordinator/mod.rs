//! Serving coordinator (L3): persistent three-party session + request
//! router + dynamic batcher + metrics, in the style of a vLLM router.
//!
//! A `Service` pins the three party threads for the lifetime of a model:
//! the model is secret-shared once, PJRT executables are warmed up once,
//! and every subsequent batch pays only the online protocol cost.  The
//! `Coordinator` in front owns the request queue and forms batches by
//! size/deadline -- batching in 3PC amortizes *rounds*, which is the
//! dominant WAN cost (the protocols are batched across samples inside the
//! engine, so a batch of 8 pays the same round count as a batch of 1).

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::engine::session::SessionConfig;
use crate::engine::{infer_batch_pooled, share_model, SharedModel};
use crate::metrics::{Histogram, Throughput};
use crate::nn::Model;
use crate::prf::PartySeeds;
use crate::protocols::Ctx;
use crate::ring::Tensor;
use crate::runtime::make_backend;
use crate::transport::{local_trio, Stats};

enum Job {
    Infer { inputs: Vec<Tensor>, batch: usize },
    Shutdown,
}

/// A persistent three-party inference service for one model.
pub struct Service {
    job_txs: Vec<Sender<Job>>,
    logits_rx: Receiver<Result<Vec<Vec<i32>>>>,
    handles: Vec<JoinHandle<Stats>>,
    pub model_name: String,
    pub setup_time: Duration,
}

impl Service {
    /// Spin up the party threads, share the model, warm the PJRT caches.
    pub fn start(model: Arc<Model>, cfg: SessionConfig) -> Result<Service> {
        let comms = local_trio(cfg.net);
        let (logits_tx, logits_rx) = channel();
        let mut job_txs = Vec::new();
        let mut handles = Vec::new();
        let (ready_tx, ready_rx) = channel();
        for comm in comms {
            let model = Arc::clone(&model);
            let cfg = cfg.clone();
            let logits_tx = logits_tx.clone();
            let ready_tx = ready_tx.clone();
            let (jtx, jrx) = channel::<Job>();
            job_txs.push(jtx);
            handles.push(thread::spawn(move || -> Stats {
                let seeds = PartySeeds::setup(cfg.session_seed, comm.id);
                let ctx = Ctx::with_cfg(&comm, &seeds, cfg.proto);
                // build the backend, warming the PJRT executable cache
                // before the first request (warmup is a no-op for native)
                let backend: Box<dyn crate::protocols::linear::LinearBackend> =
                    match make_backend(cfg.backend, &cfg.hlo_dir) {
                        Ok(b) => b,
                        Err(e) => {
                            let _ = ready_tx.send(
                                Err(anyhow!("backend: {e}")));
                            return comm.stats();
                        }
                    };
                backend.warmup(&crate::engine::hlo_keys(&model));
                let shared: SharedModel =
                    match share_model(&ctx, &model, comm.id == 1) {
                        Ok(s) => s,
                        Err(e) => {
                            let _ = ready_tx.send(Err(anyhow!("share: {e}")));
                            return comm.stats();
                        }
                    };
                // offline phase: pre-mint MSB material for several max
                // batches; topped up after each served batch, off the
                // request's critical path.
                let pool = crate::protocols::preproc::MsbPool::new();
                let per_batch = crate::engine::msb_demand(&shared, 8);
                if cfg.opts.preprocess {
                    if let Err(e) = pool.generate(&ctx, per_batch * 4) {
                        let _ = ready_tx.send(Err(anyhow!("preproc: {e}")));
                        return comm.stats();
                    }
                }
                let _ = ready_tx.send(Ok(comm.id));
                while let Ok(job) = jrx.recv() {
                    match job {
                        Job::Shutdown => break,
                        Job::Infer { inputs, batch } => {
                            let p = cfg.opts.preprocess.then_some(&pool);
                            let r = infer_batch_pooled(
                                &ctx, &shared, backend.as_ref(), cfg.opts,
                                &inputs, batch, p);
                            let failed = r.is_err();
                            if comm.id == 0 {
                                let _ = logits_tx.send(
                                    r.map(|o| o.logits)
                                     .map_err(|e| anyhow!("{e}")));
                            } else if let Err(e) = &r {
                                eprintln!("[service {}] inference failed: \
                                           {e}", comm.id);
                            }
                            if failed {
                                // a failed protocol leaves the trio
                                // desynchronized; retire this party --
                                // dropping its Comm unblocks any peer
                                // stuck in recv with WireError::Closed
                                // instead of hanging the Service
                                break;
                            }
                            // top the reservoir back up between requests
                            if cfg.opts.preprocess
                                && pool.available() < per_batch {
                                if let Err(e) =
                                    pool.generate(&ctx, per_batch * 2) {
                                    eprintln!("[service {}] preproc \
                                               top-up failed: {e}", comm.id);
                                    break;
                                }
                            }
                        }
                    }
                }
                comm.stats()
            }));
        }
        let t0 = Instant::now();
        for _ in 0..3 {
            ready_rx.recv().map_err(|_| anyhow!("party died in setup"))??;
        }
        Ok(Service {
            job_txs,
            logits_rx,
            handles,
            model_name: model.name.clone(),
            setup_time: t0.elapsed(),
        })
    }

    /// Run one batch through the session (blocking).
    pub fn infer(&self, inputs: Vec<Tensor>) -> Result<Vec<Vec<i32>>> {
        let batch = inputs.len();
        for (id, tx) in self.job_txs.iter().enumerate() {
            let job = Job::Infer {
                inputs: if id == 0 { inputs.clone() } else { vec![] },
                batch,
            };
            tx.send(job).map_err(|_| anyhow!("party {id} gone"))?;
        }
        self.logits_rx.recv().map_err(|_| anyhow!("no response"))?
    }

    /// Stop the party threads and collect their comm stats.
    pub fn shutdown(self) -> [Stats; 3] {
        for tx in &self.job_txs {
            let _ = tx.send(Job::Shutdown);
        }
        let stats: Vec<Stats> = self.handles.into_iter()
            .map(|h| h.join().unwrap_or_default()).collect();
        [stats[0], stats[1], stats[2]]
    }
}

/// One queued request.
struct Pending {
    image: Tensor,
    enqueued: Instant,
    respond: Sender<Response>,
}

/// Reply to a client.
#[derive(Clone, Debug)]
pub struct Response {
    pub logits: Vec<i32>,
    pub pred: usize,
    pub latency: Duration,
}

/// Dynamic batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(5) }
    }
}

/// Request router + dynamic batcher in front of a `Service`.
pub struct Coordinator {
    req_tx: Sender<Pending>,
    batcher: Option<JoinHandle<(Histogram, Throughput)>>,
}

impl Coordinator {
    pub fn start(svc: Service, policy: BatchPolicy) -> Coordinator {
        let (req_tx, req_rx) = channel::<Pending>();
        let batcher = thread::spawn(move || {
            let mut hist = Histogram::default();
            let mut served = 0u64;
            let t0 = Instant::now();
            loop {
                // block for the first request, then fill the batch up to
                // the deadline
                let first = match req_rx.recv() {
                    Ok(p) => p,
                    Err(_) => break, // all clients gone
                };
                let mut batch = vec![first];
                let deadline = Instant::now() + policy.max_wait;
                while batch.len() < policy.max_batch {
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    match req_rx.recv_timeout(deadline - now) {
                        Ok(p) => batch.push(p),
                        Err(_) => break,
                    }
                }
                let images: Vec<Tensor> =
                    batch.iter().map(|p| p.image.clone()).collect();
                match svc.infer(images) {
                    Ok(logits) => {
                        for (p, l) in batch.into_iter().zip(logits) {
                            let lat = p.enqueued.elapsed();
                            hist.record(lat);
                            served += 1;
                            let pred = crate::engine::argmax(&l);
                            let _ = p.respond.send(Response {
                                logits: l, pred, latency: lat,
                            });
                        }
                    }
                    Err(e) => {
                        eprintln!("[coordinator] batch failed: {e}");
                    }
                }
            }
            let _ = svc.shutdown();
            (hist, Throughput { requests: served, wall: t0.elapsed() })
        });
        Coordinator { req_tx, batcher: Some(batcher) }
    }

    /// Submit a request; returns the channel the response arrives on.
    pub fn submit(&self, image: Tensor) -> Receiver<Response> {
        let (tx, rx) = channel();
        let _ = self.req_tx.send(Pending {
            image,
            enqueued: Instant::now(),
            respond: tx,
        });
        rx
    }

    /// Drop the ingress and wait for the batcher to drain; returns the
    /// latency histogram and throughput aggregate.
    pub fn finish(mut self) -> (Histogram, Throughput) {
        drop(self.req_tx);
        self.batcher.take().unwrap().join()
            .unwrap_or((Histogram::default(), Throughput::default()))
    }
}

/// Shared-handle client helper for multi-threaded load generators.
pub type SharedCoordinator = Arc<Mutex<Coordinator>>;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::threeparty::every_op_model;
    use crate::testutil::Rng;

    #[test]
    fn batch_policy_defaults_sane() {
        let p = BatchPolicy::default();
        assert!(p.max_batch >= 1);
        assert!(p.max_wait > Duration::ZERO);
    }

    #[test]
    fn dropped_party_surfaces_as_infer_error_not_hang() {
        // Retire one party mid-session: the hardened send path turns the
        // survivors' messages to the dead peer into WireError::Closed, the
        // party threads break out of their job loops, and the Service
        // surfaces an Err to the caller instead of panicking or hanging.
        let model = Arc::new(every_op_model());
        let cfg = SessionConfig::new("artifacts/hlo");
        let svc = Service::start(model, cfg).expect("setup with all parties");
        // kill party 2's thread: it drains its job queue, hits Shutdown,
        // and drops its Comm endpoints
        svc.job_txs[2].send(Job::Shutdown).unwrap();
        let mut rng = Rng::new(3);
        let input = rng.tensor_small(&[1, 36], 15);
        let got = svc.infer(vec![input]);
        assert!(got.is_err(), "inference with a dead peer must error");
        // the remaining party threads retired cleanly: shutdown joins
        let _ = svc.shutdown();
    }
}
