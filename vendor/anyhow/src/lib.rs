//! Offline drop-in for the `anyhow` crate, covering exactly the API surface
//! this workspace uses: `Error`, `Result`, the `anyhow!` / `bail!` /
//! `ensure!` macros, and the `Context` extension trait for `Result` and
//! `Option`.  No registry access is needed to build it; replace the path
//! dependency with the real crates.io `anyhow` when a registry is
//! available -- the call sites are source-compatible.
//!
//! Differences from the real crate (acceptable for this workspace):
//! `Display` shows the full context chain ("outer: inner") instead of the
//! outermost layer only, and there is no backtrace capture.

use std::error::Error as StdError;
use std::fmt;

/// A type-erased error with a human-readable context chain.
pub struct Error {
    msg: String,
    source: Option<Box<dyn StdError + Send + Sync + 'static>>,
}

impl Error {
    /// Build an error from anything printable.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string(), source: None }
    }

    /// Wrap with an outer context layer.
    pub fn context<C: fmt::Display>(self, c: C) -> Error {
        Error { msg: format!("{c}: {}", self.msg), source: self.source }
    }

    /// The lowest-level source error, when one was captured.
    pub fn source(&self) -> Option<&(dyn StdError + 'static)> {
        self.source.as_ref().map(|e| e.as_ref() as &(dyn StdError + 'static))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// `Error` deliberately does NOT implement std::error::Error: that keeps the
// blanket conversion below coherent (same trick as the real anyhow).
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error { msg: e.to_string(), source: Some(Box::new(e)) }
    }
}

/// `anyhow::Result<T>` with the usual defaulted error parameter.
pub type Result<T, E = Error> = std::result::Result<T, E>;

#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => { $crate::Error::msg(format!($msg)) };
    ($fmt:expr, $($arg:tt)*) => { $crate::Error::msg(format!($fmt, $($arg)*)) };
    ($err:expr $(,)?) => { $crate::Error::msg($err) };
}

#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => { return Err($crate::anyhow!($($arg)*)) };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

mod private {
    use super::{Error, StdError};

    /// Sealed unification of "things `.context()` accepts as the inner
    /// error": std errors and `Error` itself.
    pub trait IntoAnyhow {
        fn into_anyhow(self) -> Error;
    }

    impl<E: StdError + Send + Sync + 'static> IntoAnyhow for E {
        fn into_anyhow(self) -> Error {
            Error::from(self)
        }
    }

    impl IntoAnyhow for Error {
        fn into_anyhow(self) -> Error {
            self
        }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(|| ..)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F)
        -> Result<T>;
}

impl<T, E: private::IntoAnyhow> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into_anyhow().context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F)
        -> Result<T> {
        self.map_err(|e| e.into_anyhow().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F)
        -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::Other, "boom")
    }

    #[test]
    fn conversions_and_macros() {
        let e: Error = io_err().into();
        assert!(e.to_string().contains("boom"));
        let e = anyhow!("x = {}", 7);
        assert_eq!(e.to_string(), "x = 7");
        let s = String::from("stringy");
        let e = anyhow!(s);
        assert_eq!(e.to_string(), "stringy");
    }

    #[test]
    fn bail_and_ensure() {
        fn f(fail: bool) -> Result<u32> {
            ensure!(!fail, "ensured {}", 1);
            if fail {
                bail!("unreachable");
            }
            Ok(3)
        }
        assert_eq!(f(false).unwrap(), 3);
        assert_eq!(f(true).unwrap_err().to_string(), "ensured 1");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: boom");
        let a: Result<()> = Err(anyhow!("inner"));
        let e = a.with_context(|| format!("layer {}", 2)).unwrap_err();
        assert_eq!(e.to_string(), "layer 2: inner");
        let n: Option<u8> = None;
        assert_eq!(n.context("missing").unwrap_err().to_string(), "missing");
    }
}
