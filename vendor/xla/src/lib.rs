//! Offline stub of the `xla` PJRT bindings.
//!
//! The container this workspace builds in has no registry access and no
//! PJRT shared library, so the real bindings cannot be compiled here.  This
//! crate mirrors the subset of the `xla` API that `cbnn::runtime` calls, but
//! every entry point fails at runtime with a clear message --
//! `PjRtClient::cpu()` errors immediately, so `PjrtRuntime::new` reports the
//! missing backend before any artifact is touched, and the engine falls back
//! to the native contraction.
//!
//! To run the AOT artifacts for real, replace this directory with the actual
//! `xla` crate (same API) and build with `--features pjrt`.

use std::fmt;

/// Stub error carrying a human-readable reason.
#[derive(Debug)]
pub struct XlaError(String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for XlaError {}

pub type Result<T> = std::result::Result<T, XlaError>;

fn stub() -> XlaError {
    XlaError(
        "xla stub: PJRT is not available in this build (vendor/xla is an \
         offline placeholder; drop in the real `xla` crate and rebuild with \
         --features pjrt)".to_string(),
    )
}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(stub())
    }

    pub fn compile(&self, _c: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(stub())
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(stub())
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_p: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(stub())
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(stub())
    }
}

pub struct Literal;

impl Literal {
    pub fn vec1(_data: &[i32]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(stub())
    }

    pub fn to_tuple1(&self) -> Result<Literal> {
        Err(stub())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(stub())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_entry_point_reports_the_stub() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x").is_err());
        let msg = PjRtClient::cpu().unwrap_err().to_string();
        assert!(msg.contains("stub"));
    }
}
