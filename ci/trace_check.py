#!/usr/bin/env python3
"""Cross-party trace validation (stdlib only, mirrors trace::merge).

Usage:
    python3 ci/trace_check.py DIR          # validate an exported trace
    python3 ci/trace_check.py --self-test  # run the built-in fixtures

DIR must hold the files `cbnn serve --trace-out DIR` writes:
trace-p{0,1,2}.jsonl (one span per line) and stats-p{0,1,2}.json (the
per-party transport::Stats sidecar).  The checks are the same ones
`cbnn trace DIR` runs via rust/src/trace/merge.rs:

  1. every span line carries the full schema with sane types;
  2. the lock-step kinds (request/op/protocol) join rank-to-rank
     within each (trace_id, kind) group: span counts, labels, and
     round counts must agree across all three parties;
  3. each party's summed `send`-flight bytes per channel equal the
     sidecar's per-channel bytes_sent rows exactly (skipped for a
     party whose sink overflowed: a partial trace cannot sum to
     lifetime totals).

Exit status 0 = consistent, 1 = problems found (all printed).
"""

import json
import os
import sys

PARTIES = 3
LOCKSTEP = ("request", "op", "protocol")
KINDS = LOCKSTEP + ("flight", "gauge")
SPAN_FIELDS = {
    "trace_id": int,
    "kind": str,
    "party": int,
    "chan": int,
    "index": int,
    "label": str,
    "wall_start_us": int,
    "wall_end_us": int,
    "virt_start_ns": int,
    "virt_end_ns": int,
    "rounds": int,
    "bytes_sent": int,
    "value": int,
}
SIDECAR_FIELDS = {
    "party": int,
    "dropped_events": int,
    "bytes_sent": int,
    "messages": int,
    "rounds": int,
    "channels": list,
}


def load_spans(path, party, problems):
    """Parse one party's JSONL, schema-checking every line."""
    spans = []
    with open(path, encoding="utf-8") as fh:
        for lineno, line in enumerate(fh, 1):
            line = line.strip()
            if not line:
                continue
            where = "%s:%d" % (os.path.basename(path), lineno)
            try:
                span = json.loads(line)
            except ValueError as exc:
                problems.append("%s: bad JSON: %s" % (where, exc))
                continue
            bad = False
            for key, typ in SPAN_FIELDS.items():
                val = span.get(key)
                if not isinstance(val, typ) or (typ is int and val < 0):
                    problems.append(
                        "%s: field '%s' missing or not a %s"
                        % (where, key, typ.__name__))
                    bad = True
            if bad:
                continue
            if span["kind"] not in KINDS:
                problems.append(
                    "%s: unknown kind '%s'" % (where, span["kind"]))
                continue
            if span["party"] != party:
                problems.append(
                    "%s: span says party %d in party %d's file"
                    % (where, span["party"], party))
                continue
            spans.append(span)
    return spans


def load_sidecar(path, party, problems):
    """Parse one party's stats sidecar; None if it is unusable."""
    name = os.path.basename(path)
    with open(path, encoding="utf-8") as fh:
        try:
            side = json.load(fh)
        except ValueError as exc:
            problems.append("%s: bad JSON: %s" % (name, exc))
            return None
    for key, typ in SIDECAR_FIELDS.items():
        if not isinstance(side.get(key), typ):
            problems.append(
                "%s: field '%s' missing or not a %s"
                % (name, key, typ.__name__))
            return None
    if side["party"] != party:
        problems.append(
            "%s: sidecar says party %d, expected %d"
            % (name, side["party"], party))
        return None
    chan_bytes = {}
    for row in side["channels"]:
        if not isinstance(row.get("chan"), int) \
                or not isinstance(row.get("bytes_sent"), int):
            problems.append("%s: malformed channel row %r" % (name, row))
            return None
        chan_bytes[row["chan"]] = row["bytes_sent"]
    side["chan_bytes"] = chan_bytes
    return side


def group(spans, kind):
    """trace_id -> that trace's spans of `kind`, in record order."""
    out = {}
    for span in spans:
        if span["kind"] == kind:
            out.setdefault(span["trace_id"], []).append(span)
    return out


def merge_check(parties):
    """The lock-step join: counts, labels, rounds (merge.rs mirror)."""
    problems = []
    joined = 0
    for kind in LOCKSTEP:
        grouped = [group(spans, kind) for spans in parties]
        ids = sorted(set().union(*(g.keys() for g in grouped)))
        for tid in ids:
            lists = [g.get(tid, []) for g in grouped]
            counts = [len(lst) for lst in lists]
            if len(set(counts)) > 1:
                problems.append(
                    "trace %d: %s span counts differ across parties: %s"
                    % (tid, kind, counts))
                continue
            for k in range(counts[0]):
                first = lists[0][k]
                for party in range(1, PARTIES):
                    span = lists[party][k]
                    if span["label"] != first["label"]:
                        problems.append(
                            "trace %d: %s span %d: label '%s' on party "
                            "0 vs '%s' on party %d"
                            % (tid, kind, k, first["label"],
                               span["label"], party))
                    elif span["rounds"] != first["rounds"]:
                        problems.append(
                            "trace %d: %s span %d ('%s'): %d rounds on "
                            "party 0 vs %d on party %d"
                            % (tid, kind, k, first["label"],
                               first["rounds"], span["rounds"], party))
                joined += 1
    return joined, problems


def check_flights(party, spans, chan_bytes):
    """Exact per-channel send-flight byte reconciliation."""
    problems = []
    traced = {}
    for span in spans:
        if span["kind"] == "flight" and span["label"] == "send":
            traced[span["chan"]] = \
                traced.get(span["chan"], 0) + span["bytes_sent"]
    expected = {c: b for c, b in chan_bytes.items() if b > 0}
    for tag in sorted(set(traced) | set(expected)):
        got = traced.get(tag, 0)
        want = expected.get(tag, 0)
        if got != want:
            problems.append(
                "party %d chan %d: traced %d bytes but "
                "transport::Stats says %d" % (party, tag, got, want))
    return problems


def check_dir(trace_dir):
    problems = []
    parties = []
    sidecars = []
    for party in range(PARTIES):
        trace = os.path.join(trace_dir, "trace-p%d.jsonl" % party)
        stats = os.path.join(trace_dir, "stats-p%d.json" % party)
        for path in (trace, stats):
            if not os.path.isfile(path):
                print("trace_check: missing %s" % path)
                return 1
        parties.append(load_spans(trace, party, problems))
        sidecars.append(load_sidecar(stats, party, problems))

    joined, merge_problems = merge_check(parties)
    problems.extend(merge_problems)

    for party in range(PARTIES):
        side = sidecars[party]
        if side is None:
            continue
        if side["dropped_events"] > 0:
            print("trace_check: party %d dropped %d span(s) -- byte "
                  "reconciliation skipped (partial trace)"
                  % (party, side["dropped_events"]))
            continue
        problems.extend(
            check_flights(party, parties[party], side["chan_bytes"]))

    traces = sorted({s["trace_id"]
                     for spans in parties for s in spans
                     if s["trace_id"] != 0})
    print("trace_check: %d trace(s), %d joined lock-step span(s), "
          "%d span(s) total"
          % (len(traces), joined, sum(len(p) for p in parties)))
    for problem in problems:
        print("trace_check: PROBLEM: %s" % problem)
    if problems:
        print("trace_check: FAIL -- %d problem(s)" % len(problems))
        return 1
    print("trace_check: OK -- rounds agree on every joined span, "
          "flight bytes reconcile with link stats")
    return 0


# -- self-test fixtures ---------------------------------------------------

def _span(party, trace_id, kind, label, rounds=0, chan=0,
          bytes_sent=0):
    return {
        "trace_id": trace_id, "kind": kind, "party": party,
        "chan": chan, "index": 0, "label": label,
        "wall_start_us": 0, "wall_end_us": 1,
        "virt_start_ns": 0, "virt_end_ns": 0,
        "rounds": rounds, "bytes_sent": bytes_sent, "value": 0,
    }


def _write_fixture(trace_dir, mutate=None, dropped=(0, 0, 0)):
    os.makedirs(trace_dir, exist_ok=True)
    for party in range(PARTIES):
        spans = [
            _span(party, 1, "request", "everyop", rounds=8),
            _span(party, 1, "op", "sign", rounds=2),
            _span(party, 1, "protocol", "msb", rounds=2),
            _span(party, 1, "flight", "send", chan=0, bytes_sent=64),
            _span(party, 1, "flight", "send", chan=0, bytes_sent=36),
            _span(party, 1, "flight", "recv", chan=0, bytes_sent=999),
        ]
        if mutate:
            mutate(party, spans)
        with open(os.path.join(trace_dir, "trace-p%d.jsonl" % party),
                  "w", encoding="utf-8") as fh:
            for span in spans:
                fh.write(json.dumps(span) + "\n")
        side = {
            "party": party, "dropped_events": dropped[party],
            "bytes_sent": 100, "messages": 2, "rounds": 2,
            "channels": [{"chan": 0, "bytes_sent": 100,
                          "messages": 2, "rounds": 2}],
        }
        with open(os.path.join(trace_dir, "stats-p%d.json" % party),
                  "w", encoding="utf-8") as fh:
            json.dump(side, fh)
            fh.write("\n")


def self_test():
    import shutil
    import tempfile

    root = tempfile.mkdtemp(prefix="trace_check_")
    failures = []

    def case(name, want, mutate=None, dropped=(0, 0, 0)):
        trace_dir = os.path.join(root, name)
        _write_fixture(trace_dir, mutate=mutate, dropped=dropped)
        got = check_dir(trace_dir)
        status = "ok" if got == want else "FAIL"
        print("self-test %-24s exit %d (want %d) .. %s"
              % (name, got, want, status))
        if got != want:
            failures.append(name)

    case("clean", 0)

    def desync(party, spans):
        if party == 2:
            spans[2]["rounds"] = 3  # protocol round diverges
    case("round-disagreement", 1, mutate=desync)

    def extra_op(party, spans):
        if party == 1:
            spans.insert(2, _span(party, 1, "op", "b2a", rounds=1))
    case("count-mismatch", 1, mutate=extra_op)

    def relabel(party, spans):
        if party == 0:
            spans[1]["label"] = "pool_bits"
    case("label-mismatch", 1, mutate=relabel)

    def short_flight(party, spans):
        if party == 1:
            spans[4]["bytes_sent"] = 35  # 99 traced vs 100 in stats
    case("byte-mismatch", 1, mutate=short_flight)

    # an overflowed sink skips the byte check instead of failing it
    case("overflow-skips-bytes", 0, mutate=short_flight,
         dropped=(0, 7, 0))

    shutil.rmtree(root, ignore_errors=True)
    if failures:
        print("self-test FAILED: %s" % ", ".join(failures))
        return 1
    print("self-test OK")
    return 0


def main(argv):
    if len(argv) != 2:
        print(__doc__.strip())
        return 2
    if argv[1] == "--self-test":
        return self_test()
    return check_dir(argv[1])


if __name__ == "__main__":
    sys.exit(main(sys.argv))
