#!/usr/bin/env python3
"""Bench-regression gate: compare a fresh bench JSON against the
committed baseline.

Usage: bench_compare.py BASELINE.json FRESH.json

Every (section, op, n) row recorded in the baseline must exist in the
fresh run with `fast_ms` no more than TOLERANCE times the baseline's
(lower is better; the `baseline_ms` column is the *slow reference arm*
inside one run, not the regression baseline, so only `fast_ms` is
gated).  Sections whose name ends in `_bytes` carry deterministic wire
accounting in the `*_ms` columns (e.g. the fusion bench's
hidden-segment bytes), so they are gated exactly: ANY divergence --
growth or shrink -- fails and names the diverging key and both byte
values, because a deterministic counter that moved is a wire-format
change someone must sign off on by re-promoting the baseline.

Section coverage is gated in both directions: a section the fresh run
produced with no baseline rows fails loudly (a new bench tier must be
promoted into the baseline, not left unwatched), and a baseline
section the fresh run never produced fails loudly (the tier silently
stopped executing).  A baseline with an empty `results` list -- the
committed stubs from before a toolchain was available -- skips the
comparison, so the job cannot fail before a real baseline has been
promoted.
"""

import json
import sys

TOLERANCE = 1.20  # fail on >20% regression


def key(row):
    return (row["section"], row["op"], row["n"])


def main() -> int:
    if len(sys.argv) != 3:
        print(__doc__)
        return 2
    base_path, fresh_path = sys.argv[1], sys.argv[2]
    with open(base_path) as f:
        base = json.load(f)
    with open(fresh_path) as f:
        fresh = json.load(f)

    base_rows = base.get("results") or []
    if not base_rows:
        print(f"{base_path}: no committed baseline yet (empty results) "
              "-- skipping comparison; promote a green run's artifact "
              "to enable the gate")
        return 0

    fresh_list = fresh.get("results") or []
    fresh_rows = {key(r): r for r in fresh_list}
    failures = []

    # section coverage must match in both directions
    base_sections = {r["section"] for r in base_rows}
    fresh_sections = {r["section"] for r in fresh_list}
    for sec in sorted(fresh_sections - base_sections):
        failures.append(
            f"section `{sec}`: fresh run produced it but the baseline "
            f"has no rows for it -- promote a baseline that includes "
            f"the new tier; the gate refuses to leave it unwatched")
    for sec in sorted(base_sections - fresh_sections):
        failures.append(
            f"section `{sec}`: recorded in the baseline but missing "
            f"entirely from the fresh run -- the bench tier did not "
            f"execute")

    for row in base_rows:
        got = fresh_rows.get(key(row))
        if got is None:
            failures.append(f"{key(row)}: row missing from fresh run")
            continue
        if row["section"].endswith("_bytes"):
            if got["fast_ms"] != row["fast_ms"]:
                delta = got["fast_ms"] - row["fast_ms"]
                failures.append(
                    f"{key(row)}: exact byte gate: {got['fast_ms']:.0f} "
                    f"bytes vs baseline {row['fast_ms']:.0f} "
                    f"({delta:+.0f}) -- byte rows are deterministic, so "
                    f"any drift is a wire-format change; re-promote the "
                    f"baseline only if it is intended")
            continue
        if got["fast_ms"] > row["fast_ms"] * TOLERANCE:
            failures.append(
                f"{key(row)}: fast_ms {got['fast_ms']:.3f} vs baseline "
                f"{row['fast_ms']:.3f} "
                f"(+{100 * (got['fast_ms'] / row['fast_ms'] - 1):.0f}%, "
                f"limit +{100 * (TOLERANCE - 1):.0f}%)")

    checked = len(base_rows)
    if failures:
        print(f"{fresh_path}: {len(failures)} gate failures "
              f"({checked} baseline rows checked):")
        for f_ in failures:
            print(f"  {f_}")
        return 1
    print(f"{fresh_path}: {checked} rows within {TOLERANCE:.2f}x of "
          f"{base_path} (byte rows exact, sections matched)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
