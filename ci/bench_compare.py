#!/usr/bin/env python3
"""Bench-regression gate: compare a fresh bench JSON against the
committed baseline.

Usage: bench_compare.py BASELINE.json FRESH.json

Every (section, op, n) row recorded in the baseline must exist in the
fresh run with `fast_ms` no more than TOLERANCE times the baseline's
(lower is better; the `baseline_ms` column is the *slow reference arm*
inside one run, not the regression baseline, so only `fast_ms` is
gated).  Sections whose name ends in `_bytes` carry deterministic wire
accounting in the `*_ms` columns (e.g. the fusion bench's
hidden-segment bytes), so they are gated exactly: any byte growth
fails.  A baseline with an empty `results` list -- the committed stubs
from before a toolchain was available -- skips the comparison, so the
job cannot fail before a real baseline has been promoted.
"""

import json
import sys

TOLERANCE = 1.20  # fail on >20% regression


def tolerance_for(row):
    """Timing rows get the noise tolerance; byte rows are exact."""
    return 1.0 if row["section"].endswith("_bytes") else TOLERANCE


def key(row):
    return (row["section"], row["op"], row["n"])


def main() -> int:
    if len(sys.argv) != 3:
        print(__doc__)
        return 2
    base_path, fresh_path = sys.argv[1], sys.argv[2]
    with open(base_path) as f:
        base = json.load(f)
    with open(fresh_path) as f:
        fresh = json.load(f)

    base_rows = base.get("results") or []
    if not base_rows:
        print(f"{base_path}: no committed baseline yet (empty results) "
              "-- skipping comparison; promote a green run's artifact "
              "to enable the gate")
        return 0

    fresh_rows = {key(r): r for r in fresh.get("results") or []}
    failures = []
    for row in base_rows:
        got = fresh_rows.get(key(row))
        if got is None:
            failures.append(f"{key(row)}: row missing from fresh run")
            continue
        tol = tolerance_for(row)
        if got["fast_ms"] > row["fast_ms"] * tol:
            failures.append(
                f"{key(row)}: fast_ms {got['fast_ms']:.3f} vs baseline "
                f"{row['fast_ms']:.3f} "
                f"(+{100 * (got['fast_ms'] / row['fast_ms'] - 1):.0f}%, "
                f"limit +{100 * (tol - 1):.0f}%)")

    checked = len(base_rows)
    if failures:
        print(f"{fresh_path}: {len(failures)}/{checked} rows regressed "
              f"past {TOLERANCE:.2f}x:")
        for f_ in failures:
            print(f"  {f_}")
        return 1
    print(f"{fresh_path}: {checked} rows within {TOLERANCE:.2f}x of "
          f"{base_path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
