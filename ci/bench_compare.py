#!/usr/bin/env python3
"""Bench-regression gate: compare a fresh bench JSON against the
committed baseline.

Usage: bench_compare.py BASELINE.json FRESH.json
       bench_compare.py --self-test

Every (section, op, n) row recorded in the baseline must exist in the
fresh run with `fast_ms` no more than TOLERANCE times the baseline's
(lower is better; the `baseline_ms` column is the *slow reference arm*
inside one run, not the regression baseline, so only `fast_ms` is
gated).  Sections whose name ends in `_bytes` or `_counts` carry
deterministic accounting in the `*_ms` columns (wire bytes, span
counts, admission-control shed counters), so they are gated exactly:
ANY divergence -- growth or shrink -- fails and names the diverging
key and both values, because a deterministic counter that moved is a
wire-format or policy change someone must sign off on by re-promoting
the baseline.

Section coverage is gated in both directions: a section the fresh run
produced with no baseline rows fails loudly (a new bench tier must be
promoted into the baseline, not left unwatched), and a baseline
section the fresh run never produced fails loudly (the tier silently
stopped executing).

A baseline with an empty `results` list FAILS the gate: every
committed BENCH_*.json carries real rows, so an empty baseline means
the baseline was clobbered or a new record was committed without
promotion -- either way the gate must not silently pass.

`--self-test` proves the gate is armed without a toolchain: it
synthesizes a baseline, then checks that (a) a fresh run 25% slower on
a timing row exits non-zero, (b) a one-byte drift on an exact row
exits non-zero, (c) a run within tolerance exits zero, and (d) an
empty baseline exits non-zero.  CI runs it before the real
comparisons, so a regression in this script is itself caught.
"""

import copy
import json
import sys

TOLERANCE = 1.20  # fail on >20% regression
EXACT_SUFFIXES = ("_bytes", "_counts")


def key(row):
    return (row["section"], row["op"], row["n"])


def compare(base, fresh, base_path="<baseline>", fresh_path="<fresh>",
            quiet=False):
    """Core gate.  Returns (exit_code, failure_messages)."""
    failures = []
    base_rows = base.get("results") or []
    if not base_rows:
        return 1, [
            f"{base_path}: baseline has an empty `results` list -- the "
            f"gate refuses to pass vacuously.  Promote a real bench "
            f"run's artifact (every committed BENCH_*.json carries "
            f"measured rows)"]

    fresh_list = fresh.get("results") or []
    fresh_rows = {key(r): r for r in fresh_list}

    # section coverage must match in both directions
    base_sections = {r["section"] for r in base_rows}
    fresh_sections = {r["section"] for r in fresh_list}
    for sec in sorted(fresh_sections - base_sections):
        failures.append(
            f"section `{sec}`: fresh run produced it but the baseline "
            f"has no rows for it -- promote a baseline that includes "
            f"the new tier; the gate refuses to leave it unwatched")
    for sec in sorted(base_sections - fresh_sections):
        failures.append(
            f"section `{sec}`: recorded in the baseline but missing "
            f"entirely from the fresh run -- the bench tier did not "
            f"execute")

    for row in base_rows:
        got = fresh_rows.get(key(row))
        if got is None:
            failures.append(f"{key(row)}: row missing from fresh run")
            continue
        if row["section"].endswith(EXACT_SUFFIXES):
            if got["fast_ms"] != row["fast_ms"]:
                delta = got["fast_ms"] - row["fast_ms"]
                failures.append(
                    f"{key(row)}: exact gate: {got['fast_ms']:.0f} "
                    f"vs baseline {row['fast_ms']:.0f} "
                    f"({delta:+.0f}) -- {'/'.join(EXACT_SUFFIXES)} rows "
                    f"are deterministic, so any drift is a wire-format "
                    f"or policy change; re-promote the baseline only if "
                    f"it is intended")
            continue
        if got["fast_ms"] > row["fast_ms"] * TOLERANCE:
            failures.append(
                f"{key(row)}: fast_ms {got['fast_ms']:.3f} vs baseline "
                f"{row['fast_ms']:.3f} "
                f"(+{100 * (got['fast_ms'] / row['fast_ms'] - 1):.0f}%, "
                f"limit +{100 * (TOLERANCE - 1):.0f}%)")

    checked = len(base_rows)
    if failures:
        if not quiet:
            print(f"{fresh_path}: {len(failures)} gate failures "
                  f"({checked} baseline rows checked):")
            for f_ in failures:
                print(f"  {f_}")
        return 1, failures
    if not quiet:
        print(f"{fresh_path}: {checked} rows within {TOLERANCE:.2f}x of "
              f"{base_path} (exact rows exact, sections matched)")
    return 0, []


def self_test() -> int:
    """Prove the gate trips on the failures it exists to catch."""
    base = {
        "bench": "selftest",
        "results": [
            {"section": "timing_sec", "op": "walk", "n": 8,
             "baseline_ms": 40.0, "fast_ms": 10.0, "speedup": 4.0},
            {"section": "wire_bytes", "op": "segment", "n": 8,
             "baseline_ms": 4096.0, "fast_ms": 4096.0, "speedup": 1.0},
            {"section": "shed_counts", "op": "queue-full", "n": 10,
             "baseline_ms": 6.0, "fast_ms": 6.0, "speedup": 1.0},
        ],
    }

    def variant(edits):
        v = copy.deepcopy(base)
        for (section, op), fields in edits.items():
            for row in v["results"]:
                if row["section"] == section and row["op"] == op:
                    row.update(fields)
        return v

    cases = [
        ("25% slowdown on a timing row must fail",
         base, variant({("timing_sec", "walk"): {"fast_ms": 12.5}}), 1),
        ("one-byte drift on a _bytes row must fail",
         base, variant({("wire_bytes", "segment"): {"fast_ms": 4097.0}}),
         1),
        ("counter drift on a _counts row must fail",
         base, variant({("shed_counts", "queue-full"): {"fast_ms": 7.0}}),
         1),
        ("a run within tolerance must pass",
         base, variant({("timing_sec", "walk"): {"fast_ms": 11.9}}), 0),
        ("an empty baseline must fail, not skip",
         {"bench": "selftest", "results": []}, base, 1),
        ("a missing section must fail",
         base, {"bench": "selftest",
                "results": [r for r in base["results"]
                            if r["section"] != "shed_counts"]}, 1),
    ]
    bad = 0
    for name, b, f, want in cases:
        got, _ = compare(b, f, quiet=True)
        verdict = "ok" if got == want else "FAILED"
        if got != want:
            bad += 1
        print(f"  self-test [{verdict}] {name} (exit {got}, want {want})")
    if bad:
        print(f"self-test: {bad}/{len(cases)} cases FAILED -- the gate "
              f"is not armed")
        return 1
    print(f"self-test: {len(cases)}/{len(cases)} cases passed -- the "
          f"gate is armed")
    return 0


def main() -> int:
    if len(sys.argv) == 2 and sys.argv[1] == "--self-test":
        return self_test()
    if len(sys.argv) != 3:
        print(__doc__)
        return 2
    base_path, fresh_path = sys.argv[1], sys.argv[2]
    with open(base_path) as f:
        base = json.load(f)
    with open(fresh_path) as f:
        fresh = json.load(f)
    code, _ = compare(base, fresh, base_path, fresh_path)
    return code


if __name__ == "__main__":
    sys.exit(main())
