"""Knowledge distillation machinery: loss identities + a short training
run must learn (loss down, accuracy above chance)."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import datasets, kd, networks
from compile import model as M


def test_cross_entropy_matches_manual():
    logits = jnp.array([[2.0, 0.0, -1.0], [0.0, 3.0, 0.0]])
    labels = jnp.array([0, 1])
    got = float(kd.cross_entropy(logits, labels))
    p = jax.nn.softmax(logits)
    want = float(-(jnp.log(p[0, 0]) + jnp.log(p[1, 1])) / 2)
    assert abs(got - want) < 1e-6


def test_kd_loss_lambda_endpoints():
    s = jnp.array([[1.0, 0.0, 0.0]])
    t = jnp.array([[0.0, 1.0, 0.0]])
    y = jnp.array([0])
    hard = float(kd.cross_entropy(s, y))
    # lambda = 1 -> pure student loss
    assert abs(float(kd.kd_loss(s, t, y, 1.0, 10.0)) - hard) < 1e-6
    # lambda = 0 -> teacher term only and scaled by T^2
    l0 = float(kd.kd_loss(s, t, y, 0.0, 1.0))
    pt = jax.nn.softmax(t)
    want = float(-jnp.sum(pt * jax.nn.log_softmax(s)))
    assert abs(l0 - want) < 1e-6


def test_temperature_softens_teacher():
    z = jnp.array([[4.0, 0.0, 0.0]])
    p1 = jax.nn.softmax(z / 1.0)
    p10 = jax.nn.softmax(z / 10.0)
    assert float(p10.max()) < float(p1.max())


def test_adam_decreases_quadratic():
    params = [{"w": jnp.array([5.0])}]
    state = kd.adam_init(params)
    for _ in range(200):
        grads = [{"w": 2 * params[0]["w"]}]
        params, state = kd.adam_step(params, grads, state, lr=0.1)
    assert abs(float(params[0]["w"][0])) < 0.5


def test_short_training_learns():
    data = datasets.load("mnist", 300, 120, seed=0)
    layers0, sh = networks.build("mnistnet1")
    layers, params = M.init_params(layers0, sh, jax.random.PRNGKey(0))
    params, hist = kd.train(layers, params, data, epochs=3, batch=50,
                            lr=3e-3)
    assert hist["loss"][-1] < hist["loss"][0]
    assert hist["val_acc"][-1] > 0.3  # >> 10% chance


def test_kd_training_runs_with_teacher():
    data = datasets.load("mnist", 200, 80, seed=1)
    t_layers0, sh = networks.build("mnistnet4")
    t_layers, t_params = M.init_params(t_layers0, sh, jax.random.PRNGKey(1))
    s_layers0, _ = networks.build("mnistnet1")
    s_layers, s_params = M.init_params(s_layers0, sh, jax.random.PRNGKey(2))
    s_params, hist = kd.train(s_layers, s_params, data, epochs=1, batch=50,
                              teacher=(t_layers, t_params), lam=0.3)
    assert len(hist["val_acc"]) == 1
    assert np.isfinite(hist["loss"][0])
