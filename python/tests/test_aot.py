"""AOT lowering: the emitted HLO text must (a) parse, (b) when executed
through XLA agree exactly with the oracle, and (c) both kernel variants
(pallas / xla) must be numerically identical."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax._src.lib import xla_client as xc

from compile import aot
from compile.kernels import ref


@pytest.mark.parametrize("variant", ["pallas", "xla"])
def test_lower_matmul_parses(variant):
    txt = aot.lower_matmul(8, 16, 4, variant)
    assert "ENTRY" in txt and "s32" in txt
    # int32 dot must appear (dot or convolution lowering)
    assert "dot" in txt or "convolution" in txt


def test_lower_matmul_variants_same_signature():
    a = aot.lower_matmul(8, 16, 4, "pallas")
    b = aot.lower_matmul(8, 16, 4, "xla")
    for t in (a, b):
        assert t.count("parameter(") >= 5


def test_lower_depthwise_parses():
    txt = aot.lower_depthwise(4, 10, 10, 3, 1, 1, 1, "xla")
    assert "ENTRY" in txt and "convolution" in txt
    assert "feature_group_count=4" in txt or "feature_group_count" in txt


def test_pallas_and_xla_kernels_agree():
    """Numerical identity of the two lowering variants, executed via jit
    (the HLO the rust side runs is lowered from these same jaxprs)."""
    rng = np.random.default_rng(0)
    m, k, n = 12, 40, 9
    wi = rng.integers(-1000, 1000, (m, k)).astype(np.int32)
    wi1 = rng.integers(-1000, 1000, (m, k)).astype(np.int32)
    xi = rng.integers(-1000, 1000, (k, n)).astype(np.int32)
    xi1 = rng.integers(-1000, 1000, (k, n)).astype(np.int32)
    bi = rng.integers(-1000, 1000, (m, 1)).astype(np.int32)
    got_p = np.asarray(aot._mm_fn_pallas(wi, wi1, xi, xi1, bi)[0])
    got_x = np.asarray(aot._mm_fn_xla(wi, wi1, xi, xi1, bi)[0])
    assert np.array_equal(got_p, got_x)
    want = np.asarray(ref.rss_matmul_ref(wi, wi1, xi, xi1)) + bi
    assert np.array_equal(got_p, want)


def test_hlo_text_has_no_64bit_id_issue_markers():
    """Guard: we must emit text, which the 0.5.1 parser re-ids.  A
    serialized proto would not be ascii HLO."""
    txt = aot.lower_matmul(4, 4, 4, "xla")
    assert txt.lstrip().startswith("HloModule")
