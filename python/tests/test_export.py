"""Quantization / folding correctness: the integer layer program must
agree with the float network it was derived from (argmax agreement), and
the serialized manifest must round-trip."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import datasets, export, networks
from compile import model as M


def _trained_ish(name, seed=0):
    """Init + one BN-stat calibration pass so folding sees real stats."""
    layers0, in_shape = networks.build(name)
    layers, params = M.init_params(layers0, in_shape,
                                   jax.random.PRNGKey(seed))
    ds = networks.REGISTRY[name][1]
    x, _ = (datasets.synth_mnist if ds == "mnist" else datasets.synth_cifar)(
        64, seed=seed)
    # run a few train-mode passes so BN mu/var move off init
    for _ in range(3):
        _, params = M.forward_float(layers, params, jnp.asarray(x),
                                    train=True, bn_momentum=0.5)
    return layers, params, in_shape, x


# Deep binary nets on *random* weights have near-tie activations, so sign
# bits flip inside the quantization error and cascade; trained nets have
# real margins (aot.py records fixed_acc vs plaintext acc on trained nets).
# Shallow nets must agree strongly even untrained.
@pytest.mark.parametrize("name,thresh", [("mnistnet1", 0.75),
                                         ("mnistnet2", 0.75),
                                         ("mnistnet3", 1 / 3),
                                         ("cifarnet2", 1 / 3)])
def test_fixed_matches_float_argmax(name, thresh):
    layers, params, in_shape, x = _trained_ish(name)
    q = export.quantize(layers, params, in_shape)
    q = export.permute_fc_after_flatten(q)
    logits_f, _ = M.forward_float(layers, params, jnp.asarray(x[:12]))
    pf = np.argmax(np.asarray(logits_f), 1)
    pq = np.array([int(np.argmax(M.forward_fixed(q, export.fixed_input(xi))))
                   for xi in x[:12]])
    assert np.mean(pf == pq) >= thresh, (pf, pq)


def test_quantize_structure_mnistnet3():
    layers, params, in_shape, _ = _trained_ish("mnistnet3")
    q = export.quantize(layers, params, in_shape)
    ops = [l["op"] for l in q]
    assert ops == ["matmul", "sign", "pool_bits", "pm1",
                   "matmul", "sign", "pool_bits", "pm1",
                   "flatten",
                   "matmul", "sign", "pm1",
                   "matmul"]


def test_relu_path_structure_mnistnet2():
    layers, params, in_shape, _ = _trained_ish("mnistnet2")
    q = export.quantize(layers, params, in_shape)
    ops = [l["op"] for l in q]
    assert ops == ["matmul", "relu", "flatten", "matmul", "sign", "pm1",
                   "matmul"]
    assert q[1]["trunc"] == q[0]["s_w"] > 0


def test_separable_becomes_depthwise_pointwise():
    layers, params, in_shape, _ = _trained_ish("cifarnet2")
    q = export.quantize(layers, params, in_shape)
    assert any(l["op"] == "depthwise" for l in q)
    # depthwise is always immediately followed by a pointwise matmul
    for i, l in enumerate(q):
        if l["op"] == "depthwise":
            assert q[i + 1]["op"] == "matmul" and q[i + 1]["k"] == 1


def test_serialize_roundtrip(tmp_path):
    layers, params, in_shape, _ = _trained_ish("mnistnet1")
    q = export.quantize(layers, params, in_shape)
    man = export.serialize("mnistnet1", "mnist", in_shape, q, str(tmp_path),
                           hlo_names=[f"h{i}" for i in range(3)])
    mpath = tmp_path / "mnistnet1.manifest.json"
    wpath = tmp_path / "mnistnet1.weights.bin"
    assert mpath.exists() and wpath.exists()
    man2 = json.loads(mpath.read_text())
    assert man2["s_in"] == export.S_IN and man2["ring_bits"] == 32
    pool = np.frombuffer(wpath.read_bytes(), dtype="<i4")
    # first matmul weights recoverable from the pool
    l0 = man2["layers"][1]  # [0] is flatten
    assert l0["op"] == "matmul"
    w = pool[l0["w"]["off"]:l0["w"]["off"] + l0["w"]["len"]]
    assert np.array_equal(w.reshape(l0["m"], l0["kdim"]),
                          np.asarray(q[1]["w"], np.int64).astype(np.int32))


def test_eval_data_format(tmp_path):
    x, y = datasets.synth_mnist(8, seed=0)
    p = tmp_path / "d.bin"
    export.export_eval_data(x, y, str(p), n=8)
    raw = np.frombuffer(p.read_bytes(), dtype="<i4")
    n, c, h, w = raw[:4]
    assert (n, c, h, w) == (8, 1, 28, 28)
    imgs = raw[4:4 + n * c * h * w].reshape(n, c, h, w)
    labels = raw[4 + n * c * h * w:]
    assert len(labels) == 8 and imgs.max() <= (1 << export.S_IN)


def test_threshold_flip_handles_negative_gamma():
    """BN gamma' < 0 must flip the comparison orientation (Eq. 8 caveat)."""
    layers0, in_shape = networks.build("mnistnet1")
    layers, params = M.init_params(layers0, in_shape, jax.random.PRNGKey(3))
    # force a negative gamma on the first BN
    bn_idx = next(i for i, l in enumerate(layers) if l["type"] == "bn")
    params[bn_idx]["gamma"] = params[bn_idx]["gamma"].at[0].set(-2.0)
    q = export.quantize(layers, params, in_shape)
    sign_l = next(l for l in q if l["op"] == "sign")
    assert sign_l["flip"][0] == -1 and np.all(sign_l["flip"][1:] == 1)
    # and the fixed forward still honors float semantics on that channel
    x, _ = datasets.synth_mnist(4, seed=4)
    lf, _ = M.forward_float(layers, params, jnp.asarray(x))
    lq = [M.forward_fixed(q, export.fixed_input(xi)) for xi in x]
    pf, pq = np.argmax(np.asarray(lf), 1), [int(np.argmax(l)) for l in lq]
    assert np.mean(np.asarray(pf) == np.asarray(pq)) >= 0.5


def test_calibrate_bounds_sign_inputs():
    """After calibration every sign/relu input on the calibration slice
    stays inside the MSB protocol headroom (2^24)."""
    from compile import model as M2
    layers, params, in_shape, x = _trained_ish("mnistnet3", seed=9)
    q = export.quantize(layers, params, in_shape)
    q = export.permute_fc_after_flatten(q)
    calib = [export.fixed_input(xi) for xi in x[:8]]
    q = export.calibrate(q, calib, bound_bits=24)
    stats = {}
    for xi in calib:
        M2.forward_fixed(q, xi, stats=stats)
    assert all(v < (1 << 24) for v in stats.values()), stats
