"""Quantization / folding correctness: the integer layer program must
agree with the float network it was derived from (argmax agreement), the
serialized manifest must round-trip, and the committed zoo fixtures must
replay their golden logits bit-exactly.

The golden-vector and malformed-manifest tests below are numpy-only so
the CI `model-parity` job can run them without jax; the quantization
tests need jax and skip where it is absent."""

import json
import os

import numpy as np
import pytest

try:
    import jax
    import jax.numpy as jnp
except ImportError:  # the model-parity CI job installs numpy only
    jax = None

from compile import datasets, export, networks
from compile import model as M

needs_jax = pytest.mark.skipif(jax is None, reason="jax not installed")

ZOO_DIR = os.path.join(os.path.dirname(__file__), "..", "..",
                       "fixtures", "zoo")


def _trained_ish(name, seed=0):
    """Init + one BN-stat calibration pass so folding sees real stats."""
    layers0, in_shape = networks.build(name)
    layers, params = M.init_params(layers0, in_shape,
                                   jax.random.PRNGKey(seed))
    ds = networks.REGISTRY[name][1]
    x, _ = (datasets.synth_mnist if ds == "mnist" else datasets.synth_cifar)(
        64, seed=seed)
    # run a few train-mode passes so BN mu/var move off init
    for _ in range(3):
        _, params = M.forward_float(layers, params, jnp.asarray(x),
                                    train=True, bn_momentum=0.5)
    return layers, params, in_shape, x


# Deep binary nets on *random* weights have near-tie activations, so sign
# bits flip inside the quantization error and cascade; trained nets have
# real margins (aot.py records fixed_acc vs plaintext acc on trained nets).
# Shallow nets must agree strongly even untrained.
@needs_jax
@pytest.mark.parametrize("name,thresh", [("mnistnet1", 0.75),
                                         ("mnistnet2", 0.75),
                                         ("mnistnet3", 1 / 3),
                                         ("cifarnet2", 1 / 3)])
def test_fixed_matches_float_argmax(name, thresh):
    layers, params, in_shape, x = _trained_ish(name)
    q = export.quantize(layers, params, in_shape)
    q = export.permute_fc_after_flatten(q)
    logits_f, _ = M.forward_float(layers, params, jnp.asarray(x[:12]))
    pf = np.argmax(np.asarray(logits_f), 1)
    pq = np.array([int(np.argmax(M.forward_fixed(q, export.fixed_input(xi))))
                   for xi in x[:12]])
    assert np.mean(pf == pq) >= thresh, (pf, pq)


@needs_jax
def test_quantize_structure_mnistnet3():
    layers, params, in_shape, _ = _trained_ish("mnistnet3")
    q = export.quantize(layers, params, in_shape)
    ops = [l["op"] for l in q]
    assert ops == ["matmul", "sign", "pool_bits", "pm1",
                   "matmul", "sign", "pool_bits", "pm1",
                   "flatten",
                   "matmul", "sign", "pm1",
                   "matmul"]


@needs_jax
def test_relu_path_structure_mnistnet2():
    layers, params, in_shape, _ = _trained_ish("mnistnet2")
    q = export.quantize(layers, params, in_shape)
    ops = [l["op"] for l in q]
    assert ops == ["matmul", "relu", "flatten", "matmul", "sign", "pm1",
                   "matmul"]
    assert q[1]["trunc"] == q[0]["s_w"] > 0


@needs_jax
def test_separable_becomes_depthwise_pointwise():
    layers, params, in_shape, _ = _trained_ish("cifarnet2")
    q = export.quantize(layers, params, in_shape)
    assert any(l["op"] == "depthwise" for l in q)
    # depthwise is always immediately followed by a pointwise matmul
    for i, l in enumerate(q):
        if l["op"] == "depthwise":
            assert q[i + 1]["op"] == "matmul" and q[i + 1]["k"] == 1


@needs_jax
def test_serialize_roundtrip(tmp_path):
    layers, params, in_shape, _ = _trained_ish("mnistnet1")
    q = export.quantize(layers, params, in_shape)
    man = export.serialize("mnistnet1", "mnist", in_shape, q, str(tmp_path),
                           hlo_names=[f"h{i}" for i in range(3)])
    mpath = tmp_path / "mnistnet1.manifest.json"
    wpath = tmp_path / "mnistnet1.weights.bin"
    assert mpath.exists() and wpath.exists()
    man2 = json.loads(mpath.read_text())
    assert man2["s_in"] == export.S_IN and man2["ring_bits"] == 32
    pool = np.frombuffer(wpath.read_bytes(), dtype="<i4")
    # first matmul weights recoverable from the pool
    l0 = man2["layers"][1]  # [0] is flatten
    assert l0["op"] == "matmul"
    w = pool[l0["w"]["off"]:l0["w"]["off"] + l0["w"]["len"]]
    assert np.array_equal(w.reshape(l0["m"], l0["kdim"]),
                          np.asarray(q[1]["w"], np.int64).astype(np.int32))


def test_eval_data_format(tmp_path):
    x, y = datasets.synth_mnist(8, seed=0)
    p = tmp_path / "d.bin"
    export.export_eval_data(x, y, str(p), n=8)
    raw = np.frombuffer(p.read_bytes(), dtype="<i4")
    n, c, h, w = raw[:4]
    assert (n, c, h, w) == (8, 1, 28, 28)
    imgs = raw[4:4 + n * c * h * w].reshape(n, c, h, w)
    labels = raw[4 + n * c * h * w:]
    assert len(labels) == 8 and imgs.max() <= (1 << export.S_IN)


@needs_jax
def test_threshold_flip_handles_negative_gamma():
    """BN gamma' < 0 must flip the comparison orientation (Eq. 8 caveat)."""
    layers0, in_shape = networks.build("mnistnet1")
    layers, params = M.init_params(layers0, in_shape, jax.random.PRNGKey(3))
    # force a negative gamma on the first BN
    bn_idx = next(i for i, l in enumerate(layers) if l["type"] == "bn")
    params[bn_idx]["gamma"] = params[bn_idx]["gamma"].at[0].set(-2.0)
    q = export.quantize(layers, params, in_shape)
    sign_l = next(l for l in q if l["op"] == "sign")
    assert sign_l["flip"][0] == -1 and np.all(sign_l["flip"][1:] == 1)
    # and the fixed forward still honors float semantics on that channel
    x, _ = datasets.synth_mnist(4, seed=4)
    lf, _ = M.forward_float(layers, params, jnp.asarray(x))
    lq = [M.forward_fixed(q, export.fixed_input(xi)) for xi in x]
    pf, pq = np.argmax(np.asarray(lf), 1), [int(np.argmax(l)) for l in lq]
    assert np.mean(np.asarray(pf) == np.asarray(pq)) >= 0.5


# --------------------------------------------------------------------------
# golden-vector cases on the committed zoo fixtures (numpy-only)
# --------------------------------------------------------------------------
def _zoo(*parts):
    return os.path.join(ZOO_DIR, *parts)


def test_golden_manifest_reloads_to_identical_logits():
    """The committed lenet5 manifest reloads and replays its exported
    golden logits bit-exactly -- the frozen-oracle contract the rust
    `tests/zoo.rs` asserts from the other side of the wire."""
    man, q = export.load_manifest(_zoo("lenet5.manifest.json"))
    assert man["version"] == export.MANIFEST_VERSION
    with open(_zoo("lenet5.golden.json")) as f:
        golden = json.load(f)
    imgs, labels = export.load_eval_data(_zoo("mnist_subset.bin"))
    assert len(labels) == golden["n"] == len(golden["logits"])
    for i in range(16):
        logits = M.forward_fixed(q, imgs[i])
        assert [int(v) for v in np.ravel(logits)] == golden["logits"][i], i


def test_manifest_reserialize_roundtrip(tmp_path):
    """load -> serialize -> load must reproduce identical logits: the
    writer and the reader are exact inverses on a real trained model."""
    man, q = export.load_manifest(_zoo("lenet5.manifest.json"))
    shape = (man["input"]["h"], man["input"]["w"], man["input"]["c"])
    export.serialize("again", man["dataset"], shape, q, str(tmp_path))
    _, q2 = export.load_manifest(str(tmp_path / "again.manifest.json"))
    imgs, _ = export.load_eval_data(_zoo("mnist_subset.bin"))
    for i in range(4):
        a = M.forward_fixed(q, imgs[i])
        b = M.forward_fixed(q2, imgs[i])
        assert np.array_equal(np.ravel(a), np.ravel(b)), i


def _mutated(tmp_path, mutate):
    """Copy the committed lenet5 pair into tmp and rewrite the manifest
    text through `mutate`; returns the path to load."""
    text = open(_zoo("lenet5.manifest.json")).read()
    (tmp_path / "m.manifest.json").write_text(mutate(text))
    (tmp_path / "m.weights.bin").write_bytes(
        open(_zoo("lenet5.weights.bin"), "rb").read())
    return str(tmp_path / "m.manifest.json")


@pytest.mark.parametrize("label,mutate", [
    ("truncated", lambda t: t[: len(t) // 2]),
    ("future-version", lambda t: t.replace('"version": 2',
                                           '"version": 99', 1)),
    ("kdim-lie", lambda t: t.replace('"kdim": ', '"kdim": 9', 1)),
    ("fc-before-flatten", lambda t: t.replace('"conv": true',
                                              '"conv": false', 1)),
])
def test_malformed_manifest_rejected(tmp_path, label, mutate):
    path = _mutated(tmp_path, mutate)
    with pytest.raises(export.ManifestError):
        export.load_manifest(path)


def test_truncated_weight_pool_rejected(tmp_path):
    text = open(_zoo("lenet5.manifest.json")).read()
    raw = open(_zoo("lenet5.weights.bin"), "rb").read()
    (tmp_path / "m.manifest.json").write_text(text)
    (tmp_path / "m.weights.bin").write_bytes(raw[: len(raw) // 2])
    with pytest.raises(export.ManifestError):
        export.load_manifest(str(tmp_path / "m.manifest.json"))


def test_out_of_pm1_binary_weight_rejected(tmp_path):
    man = json.load(open(_zoo("lenet5.manifest.json")))
    binary = next(l for l in man["layers"] if l.get("binary"))
    raw = bytearray(open(_zoo("lenet5.weights.bin"), "rb").read())
    poison = (binary["w"]["off"] + binary["w"]["len"] // 2) * 4
    raw[poison:poison + 4] = np.int32(2).tobytes()
    (tmp_path / "m.manifest.json").write_text(
        open(_zoo("lenet5.manifest.json")).read())
    (tmp_path / "m.weights.bin").write_bytes(bytes(raw))
    with pytest.raises(export.ManifestError, match="outside"):
        export.load_manifest(str(tmp_path / "m.manifest.json"))


def test_truncated_eval_data_rejected(tmp_path):
    raw = open(_zoo("mnist_subset.bin"), "rb").read()
    p = tmp_path / "cut.bin"
    p.write_bytes(raw[: len(raw) // 2])
    with pytest.raises(export.ManifestError):
        export.load_eval_data(str(p))


@needs_jax
def test_calibrate_bounds_sign_inputs():
    """After calibration every sign/relu input on the calibration slice
    stays inside the MSB protocol headroom (2^24)."""
    from compile import model as M2
    layers, params, in_shape, x = _trained_ish("mnistnet3", seed=9)
    q = export.quantize(layers, params, in_shape)
    q = export.permute_fc_after_flatten(q)
    calib = [export.fixed_input(xi) for xi in x[:8]]
    q = export.calibrate(q, calib, bound_bits=24)
    stats = {}
    for xi in calib:
        M2.forward_fixed(q, xi, stats=stats)
    assert all(v < (1 << 24) for v in stats.values()), stats
