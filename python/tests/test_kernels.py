"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles (ref.py).

hypothesis sweeps shapes and value ranges; everything is exact integer
arithmetic so comparisons are strict equality (the ring has no tolerance).
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import binary, ref, rss_linear

I32 = st.integers(min_value=-(2 ** 20), max_value=2 ** 20)


def _arr(rng, shape, lo=-(2 ** 20), hi=2 ** 20):
    return rng.integers(lo, hi, size=shape).astype(np.int32)


@settings(max_examples=25, deadline=None)
@given(m=st.integers(1, 40), k=st.integers(1, 48), n=st.integers(1, 40),
       seed=st.integers(0, 2 ** 31))
def test_rss_matmul_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    wi, wi1 = _arr(rng, (m, k)), _arr(rng, (m, k))
    xi, xi1 = _arr(rng, (k, n)), _arr(rng, (k, n))
    got = rss_linear.rss_matmul(wi, wi1, xi, xi1, bm=16, bk=16, bn=16)
    want = ref.rss_matmul_ref(wi, wi1, xi, xi1)
    assert np.array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=10, deadline=None)
@given(m=st.integers(1, 20), k=st.integers(1, 20), n=st.integers(1, 20),
       seed=st.integers(0, 2 ** 31))
def test_rss_matmul_wraps_mod_2_32(m, k, n, seed):
    """Products that overflow int32 must wrap, not saturate."""
    rng = np.random.default_rng(seed)
    big = 2 ** 30
    wi = _arr(rng, (m, k), -big, big)
    wi1 = _arr(rng, (m, k), -big, big)
    xi = _arr(rng, (k, n), -big, big)
    xi1 = _arr(rng, (k, n), -big, big)
    got = np.asarray(rss_linear.rss_matmul(wi, wi1, xi, xi1, bm=8, bk=8, bn=8),
                     dtype=np.int64)
    w64 = wi.astype(np.int64)
    w164 = wi1.astype(np.int64)
    x64 = xi.astype(np.int64)
    x164 = xi1.astype(np.int64)
    full = w64 @ x64 + w164 @ x64 + w64 @ x164
    want = ((full + 2 ** 31) % 2 ** 32) - 2 ** 31
    assert np.array_equal(got, want)


def test_rss_matmul_bias_broadcast():
    rng = np.random.default_rng(0)
    wi, wi1 = _arr(rng, (5, 7)), _arr(rng, (5, 7))
    xi, xi1 = _arr(rng, (7, 3)), _arr(rng, (7, 3))
    bi = _arr(rng, (5, 1))
    got = rss_linear.rss_matmul_bias(wi, wi1, xi, xi1, bi)
    want = np.asarray(ref.rss_matmul_ref(wi, wi1, xi, xi1)) + bi
    assert np.array_equal(np.asarray(got), want)


@settings(max_examples=20, deadline=None)
@given(c=st.integers(1, 8), n=st.integers(1, 200), seed=st.integers(0, 2 ** 31))
def test_sign_bits_kernel(c, n, seed):
    rng = np.random.default_rng(seed)
    z = _arr(rng, (c, n))
    t = _arr(rng, (c, 1), -100, 100)
    flip = rng.choice([-1, 1], size=(c, 1)).astype(np.int32)
    got = binary.sign_bits(z, t, flip, block=64)
    want = ((z - t) * flip >= 0).astype(np.int32)
    assert np.array_equal(np.asarray(got), want)


@settings(max_examples=15, deadline=None)
@given(c=st.integers(1, 6), h=st.integers(2, 12), w=st.integers(2, 12),
       seed=st.integers(0, 2 ** 31))
def test_pool_or_bits(c, h, w, seed):
    h, w = h - h % 2, w - w % 2  # even dims for 2x2/s2
    rng = np.random.default_rng(seed)
    bits = rng.integers(0, 2, size=(c, h, w)).astype(np.int32)
    got = np.asarray(binary.pool_or_bits(bits))
    want = np.asarray(ref.maxpool_or_ref(
        jnp.asarray(bits[None].transpose(0, 2, 3, 1)))).transpose(0, 3, 1, 2)[0]
    assert np.array_equal(got, want)


@settings(max_examples=10, deadline=None)
@given(c=st.integers(1, 4), h=st.integers(3, 10), w=st.integers(3, 10),
       k=st.integers(1, 3), seed=st.integers(0, 2 ** 31))
def test_depthwise_ref_vs_direct(c, h, w, k, seed):
    """rss_depthwise_ref equals the hand-computed 3-term contraction."""
    rng = np.random.default_rng(seed)
    wi = _arr(rng, (k, k, 1, c), -100, 100)
    wi1 = _arr(rng, (k, k, 1, c), -100, 100)
    xi = _arr(rng, (1, h, w, c), -100, 100)
    xi1 = _arr(rng, (1, h, w, c), -100, 100)
    got = np.asarray(ref.rss_depthwise_ref(wi, wi1, xi, xi1, pad="VALID"))
    oh, ow = h - k + 1, w - k + 1
    want = np.zeros((1, oh, ow, c), np.int64)
    for ci in range(c):
        for ky in range(k):
            for kx in range(k):
                patch = xi[0, ky:ky + oh, kx:kx + ow, ci].astype(np.int64)
                patch1 = xi1[0, ky:ky + oh, kx:kx + ow, ci].astype(np.int64)
                want[0, :, :, ci] += (
                    (int(wi[ky, kx, 0, ci]) + int(wi1[ky, kx, 0, ci])) * patch
                    + int(wi[ky, kx, 0, ci]) * patch1)
    want = ((want + 2 ** 31) % 2 ** 32) - 2 ** 31
    assert np.array_equal(got.astype(np.int64), want)


def test_im2col_ref_shapes():
    rng = np.random.default_rng(1)
    x = _arr(rng, (2, 8, 8, 3), -10, 10)
    cols, (oh, ow) = ref.im2col_ref(jnp.asarray(x), 3, 1, 1, 1)
    assert cols.shape == (2 * 8 * 8, 3 * 3 * 3)
    assert (oh, ow) == (8, 8)


def test_mxu_utilization_estimate_bounds():
    u = rss_linear.mxu_utilization_estimate(100, 700, 784)
    assert 0 < u <= 1
    assert rss_linear.mxu_utilization_estimate(128, 128, 128) == 1.0


def test_vmem_footprint_within_budget():
    # default blocking must fit comfortably in 16 MiB VMEM
    assert rss_linear.vmem_footprint_bytes(128, 128, 128) < 16 * 2 ** 20 // 4
