"""Synthetic dataset properties: determinism, shape, learnability."""

import numpy as np

from compile import datasets


def test_shapes_and_ranges():
    x, y = datasets.synth_mnist(32, seed=3)
    assert x.shape == (32, 28, 28, 1) and x.dtype == np.float32
    assert x.min() >= 0.0 and x.max() <= 1.0
    assert y.shape == (32,) and set(np.unique(y)) <= set(range(10))
    xc, yc = datasets.synth_cifar(16, seed=3)
    assert xc.shape == (16, 32, 32, 3)


def test_deterministic():
    x1, y1 = datasets.synth_mnist(20, seed=7)
    x2, y2 = datasets.synth_mnist(20, seed=7)
    assert np.array_equal(x1, x2) and np.array_equal(y1, y2)
    x3, _ = datasets.synth_mnist(20, seed=8)
    assert not np.array_equal(x1, x3)


def test_train_test_disjoint_seeds():
    xtr, ytr, xte, yte = datasets.load("mnist", 50, 50, seed=0)
    assert not np.array_equal(xtr[:10], xte[:10])


def _centroid_acc(x, y, xt, yt):
    cents = np.stack([x[y == c].reshape(np.sum(y == c), -1).mean(0)
                      for c in range(10)])
    flat = xt.reshape(len(xt), -1)
    d = ((flat[:, None, :] - cents[None]) ** 2).sum(-1)
    return float(np.mean(np.argmin(d, 1) == yt))


def test_learnable_above_chance():
    """A nearest-centroid classifier must beat 10% chance by a wide
    margin -- i.e. the synthetic task carries class signal."""
    xtr, ytr, xte, yte = datasets.load("mnist", 400, 200, seed=1)
    assert _centroid_acc(xtr, ytr, xte, yte) > 0.5
    xtr, ytr, xte, yte = datasets.load("cifar", 400, 200, seed=1)
    assert _centroid_acc(xtr, ytr, xte, yte) > 0.4


def test_not_trivially_constant_per_class():
    """Per-sample jitter: two samples of the same class differ."""
    x, y = datasets.synth_mnist(200, seed=2)
    for c in range(10):
        xs = x[y == c]
        if len(xs) >= 2:
            assert not np.array_equal(xs[0], xs[1])


def test_class_balance_roughly_uniform():
    _, y = datasets.synth_mnist(2000, seed=5)
    counts = np.bincount(y, minlength=10)
    assert counts.min() > 120  # E=200, loose bound
