"""Architecture registry: every Table-4 net builds, runs, and has the
layer counts the paper reports; separable convs give the Table-2-style
parameter reduction."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import networks
from compile import model as M


def _counts(layers):
    conv = sum(1 for l in layers if l["type"] == "conv")
    mp = sum(1 for l in layers if l["type"] == "pool")
    fc = sum(1 for l in layers if l["type"] == "fc")
    return conv, mp, fc


@pytest.mark.parametrize("name,conv,mp,fc", [
    ("mnistnet1", 0, 0, 3),
    ("mnistnet2", 1, 0, 2),
    ("mnistnet3", 2, 2, 2),
    ("mnistnet4", 2, 2, 2),
    ("cifarnet1", 7, 2, 1),
    ("cifarnet2", 9, 3, 1),
    ("cifarnet3", 9, 3, 1),
    ("cifarnet4", 11, 3, 1),
    ("cifarnet5", 17, 3, 1),
    ("cifarnet6", 13, 5, 3),
    ("cifarnet7", 13, 5, 3),
])
def test_table4_layer_counts(name, conv, mp, fc):
    layers, _ = networks.build(name)
    assert _counts(layers) == (conv, mp, fc)


@pytest.mark.parametrize("name", sorted(networks.REGISTRY))
def test_forward_shapes(name):
    layers0, in_shape = networks.build(name)
    layers, params = M.init_params(layers0, in_shape, jax.random.PRNGKey(0))
    x = jnp.zeros((2, *in_shape), jnp.float32)
    logits, _ = M.forward_float(layers, params, x)
    assert logits.shape == (2, 10)


def test_separable_param_reduction():
    """Table 2: MPC-friendly convolutions cut parameters by >60%
    (paper: -82.3% on the full-width net)."""
    l_sep, sh = networks.build("cifarnet2")
    l_typ, _ = networks.build("cifarnet2_typical")
    _, p_sep = M.init_params(l_sep, sh, jax.random.PRNGKey(0))
    _, p_typ = M.init_params(l_typ, sh, jax.random.PRNGKey(0))
    n_sep, n_typ = M.param_count(p_sep), M.param_count(p_typ)
    assert n_sep < 0.4 * n_typ, (n_sep, n_typ)


def test_sep_expansion():
    layers = [networks.conv(16, k=3, sep=True), networks.bn(),
              networks.act("sign")]
    exp = M._expand(layers)
    assert exp[0]["type"] == "dwconv" and exp[1]["type"] == "conv"
    assert exp[1]["k"] == 1


def test_sign_ste_gradient_window():
    g = jax.grad(lambda x: M.sign_ste(x).sum())(jnp.array([0.5, 2.0, -0.5]))
    assert np.array_equal(np.asarray(g), [1.0, 0.0, 1.0])


def test_teacher_resnet_runs():
    layers0, in_shape = networks.build("cifarnet8")
    layers, params = M.init_params(layers0, in_shape, jax.random.PRNGKey(1))
    x = jnp.ones((1, *in_shape), jnp.float32)
    logits, _ = M.forward_float(layers, params, x)
    assert logits.shape == (1, 10)
    assert np.all(np.isfinite(np.asarray(logits)))
