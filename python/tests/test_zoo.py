"""Model-zoo fixture gate (numpy-only, no jax): the committed manifests,
eval subsets and golden logits must stay mutually consistent, and the
python oracle must clear the committed accuracy floors when re-walking
the full subsets.  The rust side (`rust/tests/zoo.rs`) asserts the same
contracts against the secure engine; together they pin the paper's real
workload from both ends of the pipeline."""

import json
import os

import numpy as np
import pytest

from compile import export
from compile import model as M

ZOO_DIR = os.path.join(os.path.dirname(__file__), "..", "..",
                       "fixtures", "zoo")

# name -> (subset file, committed accuracy floor, minimum subset size)
ZOO = {
    "lenet5": ("mnist_subset.bin", 0.98, 256),
    "vgg7": ("cifar_subset.bin", 0.84, 128),
}


def _zoo(*parts):
    return os.path.join(ZOO_DIR, *parts)


@pytest.fixture(scope="module", params=sorted(ZOO))
def bundle(request):
    name = request.param
    subset, floor, n_min = ZOO[name]
    man, q = export.load_manifest(_zoo(f"{name}.manifest.json"))
    with open(_zoo(f"{name}.golden.json")) as f:
        golden = json.load(f)
    imgs, labels = export.load_eval_data(_zoo(subset))
    return name, floor, n_min, man, q, golden, imgs, labels


def test_fixture_shapes_agree(bundle):
    name, floor, n_min, man, q, golden, imgs, labels = bundle
    assert man["version"] == export.MANIFEST_VERSION
    assert imgs.shape[0] >= n_min, "committed subset too small"
    inp = man["input"]
    assert imgs.shape[1:] == (inp["c"], inp["h"], inp["w"])
    assert golden["n"] == len(labels) == len(golden["logits"])
    assert golden["labels"] == [int(v) for v in labels]
    assert golden["floor"] == floor, "floor drifted from the committed one"


def test_zoo_nets_are_binary_and_trunc_free(bundle):
    name, _, _, man, q, golden, _, _ = bundle
    ops = [l["op"] for l in man["layers"]]
    assert "relu" not in ops, (
        "zoo nets must be sign-only so every secure walk is bit-exact")
    binary = [l for l in man["layers"] if l.get("binary")]
    assert len(binary) >= 3, "expected a binary hidden chain"
    assert not any("b" in l for l in binary), "binary layers are bias-free"


def test_full_subset_accuracy_clears_floor(bundle):
    """Re-walk the whole committed subset and match the exported
    accuracy exactly -- any drift means oracle and fixtures diverged."""
    name, floor, _, man, q, golden, imgs, labels = bundle
    preds = []
    for i in range(imgs.shape[0]):
        logits = M.forward_fixed(q, imgs[i])
        row = [int(v) for v in np.ravel(logits)]
        assert row == golden["logits"][i], f"{name}: logits row {i}"
        preds.append(int(np.argmax(np.ravel(logits))))
    acc = float(np.mean(np.asarray(preds) == np.asarray(labels)))
    assert acc >= floor, f"{name}: accuracy {acc:.4f} below floor {floor}"
    assert abs(acc - golden["accuracy"]) < 1e-9
