"""Quantization, BN folding, and artifact serialization.

Turns a trained float network into the integer *layer program* the secure
engine runs:

* weights -> int32 fixed point (S_W fractional bits)
* BN + Sign  -> per-channel integer threshold + orientation flip (Eq. 8)
* BN + ReLU  -> folded into the preceding linear layer's W, b (Eq. 10/11)
* maxpool after Sign -> `pool_bits` (the Sign-fused OR pooling, Sec. 3.6)
* activations between layers are exact ring integers:
  bits {0,1} -> pm1 {-1,+1} before the next linear (local on shares)

The same program is (a) executed by model.forward_fixed as the python
oracle and (b) serialized to manifest.json + weights.bin for rust.
"""

from __future__ import annotations

import json
import os

import numpy as np

from . import model as M

S_IN = 7     # input fractional bits
S_W = 12     # weight fractional bits (upper bound; see _fit_weight_scale)
BN_EPS = 1e-5
_SAFE_BITS = 30   # per-layer |z| must stay below 2^_SAFE_BITS (headroom 2)

# Weight-manifest schema version.  v1 = the unversioned legacy schema
# (no `version` key); v2 adds the key itself plus per-layer
# `binary: true` markers whose weight planes are exact {-1,+1} with no
# bias.  The rust loader accepts 1..=MANIFEST_VERSION and rejects
# anything newer with a typed error.
MANIFEST_VERSION = 2


class ManifestError(ValueError):
    """A manifest/weights pair that cannot be loaded: version mismatch,
    out-of-range pool reference, non-+-1 binary plane, or a layer graph
    whose declared shapes lie.  Mirrored by `nn::LoadError` in rust."""


def _same_pads(h, k, stride):
    out = -(-h // stride)
    total = max((out - 1) * stride + k - h, 0)
    return total // 2, total - total // 2


def _pads(h, k, stride, pad):
    if pad == "VALID":
        return 0, 0
    return _same_pads(h, k, stride)


def _q(x, bits):
    return np.asarray(np.round(np.asarray(x, np.float64) * (1 << bits)),
                      np.int64)


def _fit_weight_scale(w2d, max_in, s_start=S_W):
    """Pick the largest weight scale <= s_start such that the worst-case
    |z| = max_row( sum_K |w_int| ) * max_in stays below 2^_SAFE_BITS.

    w2d: float weights already shaped (out, K).  max_in: worst-case |a|
    of the ring input (1 for {-1,+1} activations, ~2^{s_act+2} for
    fixed-point ReLU/image inputs, BN keeps those near unit scale).
    """
    s = s_start
    while s > 2:
        wq = _q(w2d, s)
        bound = np.abs(wq).sum(axis=1).max() * max_in
        if bound < (1 << _SAFE_BITS):
            return wq, s
        s -= 1
    return _q(w2d, 2), 2


def quantize(layers, params, input_shape):
    """float net -> integer layer program (list of dicts of numpy arrays).

    layers must already be expanded (model._expand / init_params output).
    """
    q = []
    h, w, c = input_shape
    s_act = S_IN                 # current activation scale (fraction bits)
    spatial = True               # are we still in CHW-land?
    prev_was_dw = False          # inside a separable conv pair?
    i = 0
    n = len(layers)
    while i < n:
        l, p = layers[i], params[i]
        t = l["type"]
        if t in ("conv", "dwconv", "fc"):
            # peek at BN / activation that follow
            bn_p, act_fn, j = None, None, i + 1
            if j < n and layers[j]["type"] == "bn":
                bn_p = params[j]
                j += 1
            if j < n and layers[j]["type"] == "act":
                act_fn = layers[j]["fn"]
                j += 1
            gamma_p = beta_p = None
            if bn_p is not None:
                g = np.asarray(bn_p["gamma"], np.float64)
                v = np.asarray(bn_p["var"], np.float64)
                mu = np.asarray(bn_p["mu"], np.float64)
                be = np.asarray(bn_p["beta"], np.float64)
                gamma_p = g / np.sqrt(v + BN_EPS)          # gamma'
                beta_p = be - gamma_p * mu                  # beta'

            wf = np.asarray(p["w"], np.float64)
            bf = np.asarray(p.get("b", 0.0), np.float64)
            wbin = bool(l.get("wbin"))
            fold_wb = bn_p is not None and act_fn != "sign"
            if wbin and fold_wb:
                raise ValueError(
                    "binary-weight layer must keep its BN folded into the "
                    "sign threshold (act must be sign), not into W/b")
            if fold_wb:                                     # Eq. 10/11 fold
                wf = wf * gamma_p                           # broadcast cout
                bf = beta_p + gamma_p * bf

            def _fit(w2d, max_in, s_start=S_W):
                """Quantize one (out, K) weight block: exact +-1 planes at
                scale 0 for binary layers, fitted fixed point otherwise."""
                if wbin:
                    return (np.where(np.asarray(w2d, np.float64) >= 0,
                                     1, -1).astype(np.int64), 0)
                return _fit_weight_scale(w2d, max_in, s_start=s_start)

            max_in = 1 if s_act == 0 else 4 << s_act
            # Separable-conv pairs chain two linear layers with no
            # rescaling point between them, so cap each half's weight
            # scale to keep the composed scale inside the MSB headroom
            # (DESIGN.md "Protocol round/byte budget").
            sep_cap = 7 if (t == "dwconv" or prev_was_dw) else S_W
            if t == "fc":
                if spatial:
                    raise ValueError("fc before flatten unsupported")
                wq, s_w = _fit(wf.T, max_in)                # (out, in)
                s_z = s_act + s_w
                ql = {"op": "matmul", "conv": False, "w": wq,
                      "m": wq.shape[0], "kdim": wq.shape[1]}
                if not wbin:
                    ql["b"] = _q(bf, s_z)
                cout = wq.shape[0]
            elif t == "conv":
                k, stride = l["k"], l["stride"]
                pl_, ph_ = _pads(h, k, stride, l["pad"])
                cout = wf.shape[-1]
                # HWIO -> (cout, K) with K index ((ky*k)+kx)*cin + cin_idx
                wq, s_w = _fit(
                    np.transpose(wf, (3, 0, 1, 2)).reshape(cout, -1), max_in,
                    s_start=sep_cap)
                s_z = s_act + s_w
                ql = {"op": "matmul", "conv": True, "w": wq,
                      "m": cout, "kdim": wq.shape[1],
                      "k": k, "stride": stride, "pad_lo": pl_, "pad_hi": ph_,
                      "cout": cout}
                if not wbin:
                    ql["b"] = _q(bf, s_z)
                oh = (h + pl_ + ph_ - k) // stride + 1
                ow = (w + pl_ + ph_ - k) // stride + 1
                h, w, c = oh, ow, cout
            else:                                           # dwconv
                k, stride = l["k"], l["stride"]
                pl_, ph_ = _pads(h, k, stride, l["pad"])
                # (k,k,1,C) -> (C, k*k) row per channel, K index ky*k+kx
                wq, s_w = _fit(
                    np.transpose(wf[:, :, 0, :], (2, 0, 1)).reshape(c, -1),
                    max_in, s_start=sep_cap)
                s_z = s_act + s_w
                ql = {"op": "depthwise", "w": wq,
                      "k": k, "stride": stride, "pad_lo": pl_, "pad_hi": ph_,
                      "cout": c}
                if fold_wb:
                    ql["b"] = _q(bf, s_z)
                oh = (h + pl_ + ph_ - k) // stride + 1
                ow = (w + pl_ + ph_ - k) // stride + 1
                h, w = oh, ow
                cout = c
            ql["n"] = 1 if t == "fc" else h * w
            ql["s_in"], ql["s_out"], ql["s_w"] = s_act, s_z, s_w
            if wbin:
                ql["binary"] = True
            q.append(ql)
            s_act = s_z
            prev_was_dw = t == "dwconv"

            if act_fn == "sign":
                if bn_p is not None:                        # Eq. 8 fold
                    gp = np.broadcast_to(gamma_p, (cout,)).copy()
                    bp = np.broadcast_to(beta_p, (cout,)).copy()
                    flip = np.where(gp >= 0, 1, -1).astype(np.int64)
                    with np.errstate(divide="ignore", invalid="ignore"):
                        tf = np.where(np.abs(gp) > 1e-12, -bp / gp, 0.0)
                    tq = _q(np.clip(tf, -(1 << 12), 1 << 12), s_z)
                else:
                    tq = np.zeros(cout, np.int64)
                    flip = np.ones(cout, np.int64)
                q.append({"op": "sign", "t": tq, "flip": flip, "c": cout})
                # pool over sign bits?
                if j < n and layers[j]["type"] == "pool":
                    pk = layers[j]
                    q.append({"op": "pool_bits", "k": pk["k"],
                              "stride": pk["stride"], "c": cout})
                    h = (h - pk["k"]) // pk["stride"] + 1
                    w = (w - pk["k"]) // pk["stride"] + 1
                    j += 1
                q.append({"op": "pm1"})
                s_act = 0
            elif act_fn == "relu":
                q.append({"op": "relu", "trunc": s_w})
                s_act = s_z - s_w
            i = j
        elif t == "pool":
            raise ValueError("maxpool outside the sign-fused path "
                             "(use act sign before pool)")
        elif t == "flatten":
            q.append({"op": "flatten", "c": c, "h": h, "w": w})
            spatial = False
            i += 1
        elif t in ("bn", "act"):
            raise ValueError(f"dangling {t} at {i}")
        else:
            raise ValueError(f"unsupported secure layer {t}")
    # the trailing pm1 (if any) feeds the next linear; a net ending in
    # sign+pm1 would be odd -- nets end with fc logits, so drop trailing pm1
    if q and q[-1]["op"] == "pm1":
        q.pop()
    return q


def calibrate(q, images, bound_bits=24, margin=1, max_iters=5, log=None):
    """Keep every secure-comparison input inside the MSB/trunc protocol's
    |x| < 2^bound_bits headroom (rust ProtoConfig.bound_bits).

    Runs the integer program over calibration images, measures the max
    |d| feeding each sign and the max |z| feeding each relu, and when a
    layer exceeds 2^(bound-margin), right-scales that layer's quantized
    (w, b, t) by the excess power of two.  Sign is scale-invariant so
    semantics are preserved exactly; relu layers also shrink their
    truncation amount so downstream scales are unchanged.
    """
    from . import model as M
    limit = 1 << (bound_bits - margin)
    for _ in range(max_iters):
        stats = {}
        for x in images:
            M.forward_fixed(q, x, stats=stats)
        dirty = False
        for j, l in enumerate(q):
            if l["op"] not in ("sign", "relu"):
                continue
            peak = stats.get(id(l), 0)
            if peak < limit:
                continue
            excess = int(np.ceil(np.log2(max(peak, 1) / limit))) + 1
            lin = q[j - 1]
            assert lin["op"] in ("matmul", "depthwise"), \
                f"op before {l['op']} is {lin['op']}"
            if lin.get("binary"):
                # a +-1 plane cannot be right-scaled without ceasing to
                # be +-1; binary layers are structurally bounded anyway
                # (|d| <= K + |t| << 2^bound_bits), so reaching here
                # means the threshold fold produced garbage
                raise RuntimeError(
                    f"calibration wants to rescale binary layer {j - 1} "
                    f"(peak {peak}); binary sign inputs must stay inside "
                    f"headroom by construction")
            scale = 1 << excess
            rs = lambda v: np.asarray(np.round(
                np.asarray(v, np.float64) / scale), np.int64)
            lin["w"] = rs(lin["w"])
            if lin.get("b") is not None:
                lin["b"] = rs(lin["b"])
            lin["s_out"] = int(lin["s_out"]) - excess
            lin["s_w"] = int(lin["s_w"]) - excess
            if l["op"] == "sign":
                l["t"] = rs(l["t"])
            else:
                l["trunc"] = max(0, int(l["trunc"]) - excess)
            dirty = True
            if log:
                log(f"[calibrate] layer {j - 1}: peak 2^"
                    f"{np.log2(max(peak, 1)):.1f} -> scaled down {excess} bits")
        if not dirty:
            return q
    raise RuntimeError("calibration did not converge")


def permute_fc_after_flatten(q):
    """Training flattens NHWC; the engine flattens CHW.  Permute the first
    fc weight after each flatten so both agree on CHW ordering."""
    for idx, l in enumerate(q):
        if l["op"] == "flatten":
            ch, hh, ww = l["c"], l["h"], l["w"]
            for l2 in q[idx + 1:]:
                if l2["op"] == "matmul":
                    wq = l2["w"]                       # (out, H*W*C nhwc)
                    perm = np.arange(ch * hh * ww).reshape(hh, ww, ch)
                    perm = np.transpose(perm, (2, 0, 1)).reshape(-1)
                    l2["w"] = wq[:, perm]              # now CHW-ordered
                    break
    return q


# --------------------------------------------------------------------------
# serialization
# --------------------------------------------------------------------------
def _wrap_i32(a):
    a = np.asarray(a, np.int64) & M.MASK32
    a = np.where(a >= 1 << 31, a - (1 << 32), a)
    return a.astype(np.int32)


class BinWriter:
    def __init__(self):
        self.buf = bytearray()

    def tensor(self, a):
        a = _wrap_i32(a)
        off = len(self.buf) // 4
        self.buf += a.astype("<i4").tobytes()
        return {"off": off, "len": int(a.size)}


def serialize(name, dataset, input_shape, q, out_dir, hlo_names=None):
    """Write manifest.json + weights.bin.  hlo_names: per-linear-layer HLO
    artifact basename (filled by aot.py)."""
    wtr = BinWriter()
    layers_js = []
    li = 0
    for l in q:
        js = {"op": l["op"]}
        for key in ("k", "stride", "pad_lo", "pad_hi", "m", "kdim", "n",
                    "cout", "c", "h", "w", "trunc", "s_in", "s_out", "s_w",
                    "conv", "binary"):
            if key in l and not isinstance(l[key], np.ndarray):
                js[key] = l[key] if not isinstance(l[key], (np.integer,)) \
                    else int(l[key])
        if l["op"] in ("matmul", "depthwise", "sign"):
            for key in ("w", "b", "t", "flip"):
                if key in l and l[key] is not None:
                    js[key] = wtr.tensor(l[key])
        if l["op"] in ("matmul", "depthwise"):
            if hlo_names:
                js["hlo"] = hlo_names[li]
            li += 1
        layers_js.append(js)
    manifest = {
        "version": MANIFEST_VERSION,
        "name": name, "dataset": dataset,
        "input": {"c": input_shape[2], "h": input_shape[0],
                  "w": input_shape[1]},
        "s_in": S_IN, "s_w": S_W, "ring_bits": 32,
        "layers": layers_js,
    }
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, f"{name}.manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    with open(os.path.join(out_dir, f"{name}.weights.bin"), "wb") as f:
        f.write(bytes(wtr.buf))
    return manifest


def export_eval_data(x, y, out_path, n=256):
    """Fixed-point eval images: header [n, c, h, w] i32 then images CHW
    then labels i32."""
    xs = np.transpose(x[:n], (0, 3, 1, 2))              # NHWC -> NCHW
    xq = _wrap_i32(_q(xs, S_IN))
    hdr = np.array([len(xq), *xq.shape[1:]], np.int32)
    with open(out_path, "wb") as f:
        f.write(hdr.astype("<i4").tobytes())
        f.write(xq.astype("<i4").tobytes())
        f.write(np.asarray(y[:n], np.int32).astype("<i4").tobytes())


def fixed_input(x_nhwc):
    """One NHWC float image -> (C,H,W) int64 ring input."""
    return _q(np.transpose(x_nhwc, (2, 0, 1)), S_IN)


# --------------------------------------------------------------------------
# deserialization (the python mirror of the rust loader)
# --------------------------------------------------------------------------
def _pool_slice(pool, ref, what):
    if (not isinstance(ref, dict) or "off" not in ref or "len" not in ref):
        raise ManifestError(f"{what}: malformed pool reference {ref!r}")
    off, ln = int(ref["off"]), int(ref["len"])
    if off < 0 or ln < 0 or off + ln > pool.size:
        raise ManifestError(
            f"{what}: pool reference off={off} len={ln} exceeds weight "
            f"pool of {pool.size} elements")
    return pool[off:off + ln].astype(np.int64)


def load_manifest(path):
    """Load `<name>.manifest.json` (+ sibling `.weights.bin`) back into a
    layer program runnable by `model.forward_fixed`.

    Raises `ManifestError` on a version the loader does not speak, pool
    references outside the weight pool, binary planes with values outside
    {-1,+1}, or a layer graph whose declared shapes do not chain -- the
    same rejections `nn::LoadError` types on the rust side.  Returns
    (manifest_dict, qlayers).
    """
    with open(path) as f:
        try:
            man = json.load(f)
        except json.JSONDecodeError as e:
            raise ManifestError(f"{path}: not valid JSON: {e}") from e
    version = int(man.get("version", 1))
    if not 1 <= version <= MANIFEST_VERSION:
        raise ManifestError(
            f"manifest version {version} unsupported (loader speaks "
            f"1..={MANIFEST_VERSION})")
    for key in ("name", "dataset", "input", "ring_bits", "layers"):
        if key not in man:
            raise ManifestError(f"manifest missing required key `{key}`")
    if man["ring_bits"] != 32:
        raise ManifestError(f"ring_bits {man['ring_bits']} != 32")
    wpath = str(path).replace(".manifest.json", ".weights.bin")
    pool = np.frombuffer(open(wpath, "rb").read(), dtype="<i4")

    inp = man["input"]
    c, h, w = int(inp["c"]), int(inp["h"]), int(inp["w"])
    spatial, feat = True, None
    q = []
    for i, js in enumerate(man["layers"]):
        op = js.get("op")
        what = f"layer {i} ({op})"
        l = {k: v for k, v in js.items()}
        if op == "matmul":
            l["w"] = _pool_slice(pool, js["w"], what)
            m, kdim = int(js["m"]), int(js["kdim"])
            if l["w"].size != m * kdim:
                raise ManifestError(
                    f"{what}: weight plane holds {l['w'].size} values, "
                    f"declared m*kdim = {m * kdim}")
            l["w"] = l["w"].reshape(m, kdim)
            if "b" in js:
                l["b"] = _pool_slice(pool, js["b"], what)
                if l["b"].size != m:
                    raise ManifestError(f"{what}: bias len {l['b'].size} "
                                        f"!= m {m}")
            if js.get("binary"):
                if "b" in js:
                    raise ManifestError(f"{what}: binary layer carries a "
                                        f"bias")
                if not np.isin(l["w"], (-1, 1)).all():
                    raise ManifestError(
                        f"{what}: binary plane has values outside +-1")
            if js.get("conv"):
                if not spatial:
                    raise ManifestError(f"{what}: conv after flatten")
                k, stride = int(js["k"]), int(js["stride"])
                pl_, ph_ = int(js["pad_lo"]), int(js["pad_hi"])
                if kdim != k * k * c:
                    raise ManifestError(
                        f"{what}: kdim {kdim} != k*k*cin = {k * k * c}")
                h = (h + pl_ + ph_ - k) // stride + 1
                w = (w + pl_ + ph_ - k) // stride + 1
                if h <= 0 or w <= 0:
                    raise ManifestError(f"{what}: kernel {k} does not fit "
                                        f"the activation")
                c = int(js["cout"])
                if m != c:
                    raise ManifestError(f"{what}: m {m} != cout {c}")
            else:
                if spatial:
                    raise ManifestError(f"{what}: fc before flatten")
                if kdim != feat:
                    raise ManifestError(
                        f"{what}: kdim {kdim} != incoming features {feat}")
                feat = m
        elif op == "depthwise":
            if not spatial:
                raise ManifestError(f"{what}: depthwise after flatten")
            k, stride = int(js["k"]), int(js["stride"])
            l["w"] = _pool_slice(pool, js["w"], what)
            if l["w"].size != c * k * k:
                raise ManifestError(
                    f"{what}: weight plane holds {l['w'].size} values, "
                    f"declared c*k*k = {c * k * k}")
            l["w"] = l["w"].reshape(c, k * k)
            if js.get("binary") and not np.isin(l["w"], (-1, 1)).all():
                raise ManifestError(
                    f"{what}: binary plane has values outside +-1")
            pl_, ph_ = int(js["pad_lo"]), int(js["pad_hi"])
            h = (h + pl_ + ph_ - k) // stride + 1
            w = (w + pl_ + ph_ - k) // stride + 1
            if h <= 0 or w <= 0:
                raise ManifestError(f"{what}: kernel {k} does not fit")
        elif op == "sign":
            l["t"] = _pool_slice(pool, js["t"], what)
            l["flip"] = _pool_slice(pool, js["flip"], what)
            want = c if spatial else feat
            if l["t"].size != want or l["flip"].size != want:
                raise ManifestError(
                    f"{what}: threshold/flip len != channel count {want}")
        elif op == "pool_bits":
            k, s = int(js["k"]), int(js["stride"])
            h, w = (h - k) // s + 1, (w - k) // s + 1
            if h <= 0 or w <= 0:
                raise ManifestError(f"{what}: pool {k} does not fit")
        elif op == "flatten":
            if (int(js["c"]), int(js["h"]), int(js["w"])) != (c, h, w):
                raise ManifestError(
                    f"{what}: declares {js['c']}x{js['h']}x{js['w']}, "
                    f"activation is {c}x{h}x{w}")
            feat = c * h * w
            spatial = False
        elif op in ("pm1", "relu"):
            pass
        else:
            raise ManifestError(f"{what}: unknown op")
        q.append(l)
    return man, q


def load_eval_data(path):
    """Read an export_eval_data file back: ((n,c,h,w) int64 images,
    int labels)."""
    raw = np.frombuffer(open(path, "rb").read(), dtype="<i4")
    if raw.size < 4:
        raise ManifestError(f"{path}: truncated eval-data header")
    n, c, h, w = (int(v) for v in raw[:4])
    per = c * h * w
    if raw.size != 4 + n * per + n:
        raise ManifestError(
            f"{path}: payload holds {raw.size - 4} values, header "
            f"declares {n * per + n}")
    imgs = raw[4:4 + n * per].astype(np.int64).reshape(n, c, h, w)
    labels = raw[4 + n * per:].astype(np.int64)
    return imgs, labels
