"""Deterministic synthetic stand-ins for MNIST and CIFAR-10.

The paper evaluates on MNIST and CIFAR-10.  This environment has no network
access, so we substitute *deterministic, seeded* synthetic datasets with the
exact same tensor shapes (28x28x1 / 32x32x3, 10 classes).  The secure
protocols are data-oblivious -- their cost depends only on shapes -- so all
time/communication numbers are unaffected.  Accuracy *trends* (KD helps,
separable convs cost ~2%) are reproduced on the synthetic task; see
DESIGN.md "Substitutions".

Each class is a parametric pattern family (oriented gratings + gaussian
blobs) with per-sample jitter, so the task is learnable but not linearly
trivial at high noise.
"""

from __future__ import annotations

import numpy as np

NUM_CLASSES = 10


def _rng(seed: int) -> np.random.Generator:
    return np.random.default_rng(np.random.PCG64(seed))


def _pattern(h: int, w: int, cls: int, rng: np.random.Generator,
             noise: float) -> np.ndarray:
    """One sample of the class-`cls` pattern family on an h x w grid."""
    yy, xx = np.mgrid[0:h, 0:w].astype(np.float32)
    yy = yy / h - 0.5
    xx = xx / w - 0.5
    # class-specific base orientation + frequency (deterministic in cls)
    theta = np.pi * cls / NUM_CLASSES
    freq = 3.0 + 1.5 * (cls % 5)
    # per-sample jitter
    dt = rng.normal(0.0, 0.08)
    dp = rng.uniform(0.0, 2 * np.pi)
    u = np.cos(theta + dt) * xx + np.sin(theta + dt) * yy
    img = 0.5 + 0.5 * np.sin(2 * np.pi * freq * u + dp)
    # class-specific blob: position on a ring, radius varies with class
    ang = 2 * np.pi * cls / NUM_CLASSES + rng.normal(0.0, 0.15)
    cy, cx = 0.30 * np.sin(ang), 0.30 * np.cos(ang)
    r2 = (yy - cy) ** 2 + (xx - cx) ** 2
    img += 0.9 * np.exp(-r2 / (2 * (0.06 + 0.015 * (cls % 3)) ** 2))
    img += rng.normal(0.0, noise, size=(h, w)).astype(np.float32)
    return np.clip(img, 0.0, 1.0).astype(np.float32)


def synth_mnist(n: int, seed: int = 0, noise: float = 0.25):
    """Synthetic MNIST: x in [0,1]^{n,28,28,1}, y in {0..9}^n."""
    rng = _rng(seed)
    y = rng.integers(0, NUM_CLASSES, size=n).astype(np.int32)
    x = np.stack([_pattern(28, 28, int(c), rng, noise) for c in y])
    return x[..., None], y


def synth_cifar(n: int, seed: int = 0, noise: float = 0.30):
    """Synthetic CIFAR-10: x in [0,1]^{n,32,32,3}, y in {0..9}^n.

    Channels carry correlated but distinct pattern phases plus a
    class-conditional colour cast, mimicking natural-image channel
    correlation.
    """
    rng = _rng(seed + 1)
    y = rng.integers(0, NUM_CLASSES, size=n).astype(np.int32)
    xs = []
    for c in y:
        base = _pattern(32, 32, int(c), rng, noise)
        cast = 0.25 * np.array([np.cos(2 * np.pi * c / 10),
                                np.cos(2 * np.pi * c / 10 + 2.1),
                                np.cos(2 * np.pi * c / 10 + 4.2)],
                               dtype=np.float32)
        chans = [np.clip(base * (0.8 + 0.2 * k) + cast[k]
                         + rng.normal(0, noise / 2, (32, 32)).astype(np.float32),
                         0.0, 1.0)
                 for k in range(3)]
        xs.append(np.stack(chans, axis=-1))
    return np.stack(xs).astype(np.float32), y


def load(name: str, n_train: int, n_test: int, seed: int = 0):
    """Return (x_train, y_train, x_test, y_test) for 'mnist' | 'cifar'."""
    gen = {"mnist": synth_mnist, "cifar": synth_cifar}[name]
    xtr, ytr = gen(n_train, seed=seed)
    xte, yte = gen(n_test, seed=seed + 10_000)
    return xtr, ytr, xte, yte
