"""L2: customized-BNN model in JAX.

Two forward paths:

* `forward_float`  -- differentiable float path used for (KD) training.
  Binary activations use a straight-through estimator; separable
  convolutions are expanded to depthwise + pointwise; BN uses batch stats
  at train time and running stats at eval time.

* `forward_fixed`  -- the *integer ring* path over the quantized/folded
  layer program produced by export.py.  This mirrors, operation for
  operation and in the same (C, H*W) channel-major layout, what the rust
  secure engine computes on reconstructed values, and is the bit-exact
  oracle for the golden tests.
"""

from __future__ import annotations

import numpy as np

# jax is only needed for the float/training path; the fixed-point oracle
# below is pure numpy so fixture-verification environments (the CI
# model-parity job) can import this module without a jax install.
try:
    import jax
    import jax.numpy as jnp
except ImportError:  # pragma: no cover - exercised by the CI parity job
    jax = None
    jnp = None

from . import networks

MASK32 = (1 << 32) - 1


# --------------------------------------------------------------------------
# straight-through sign
# --------------------------------------------------------------------------
if jax is not None:
    @jax.custom_vjp
    def sign_ste(x):
        return jnp.where(x >= 0, 1.0, -1.0)

    def _sign_fwd(x):
        return sign_ste(x), x

    def _sign_bwd(res, g):
        x = res
        return (g * (jnp.abs(x) <= 1.0).astype(g.dtype),)

    sign_ste.defvjp(_sign_fwd, _sign_bwd)

    def sign_ste_w(w):
        """Weight binarization: sign forward, *identity* backward.  Unlike
        the activation STE (whose |x|<=1 gate matches the paper), latent
        weights must keep receiving gradients even after drifting past
        +-1, or they freeze at their first saturation."""
        return w + jax.lax.stop_gradient(jnp.where(w >= 0, 1.0, -1.0) - w)


# --------------------------------------------------------------------------
# parameter init
# --------------------------------------------------------------------------
def _expand(layers):
    """Expand sep-convs into explicit depthwise + pointwise sub-layers."""
    out = []
    for l in layers:
        if l["type"] == "conv" and l.get("sep") and l["k"] > 1:
            out.append({"type": "dwconv", "k": l["k"], "stride": l["stride"],
                        "pad": l["pad"], "wbin": l.get("wbin", False)})
            out.append({"type": "conv", "k": 1, "stride": 1, "pad": "SAME",
                        "cout": l["cout"], "sep": False,
                        "wbin": l.get("wbin", False)})
        else:
            out.append(dict(l))
    return out


def init_params(layers, input_shape, key):
    """He-style init; returns (expanded_layers, params list)."""
    layers = _expand(layers)
    params = []
    h, w, c = input_shape
    feat = None
    for l in layers:
        t = l["type"]
        if t == "conv":
            k, co = l["k"], l["cout"]
            key, sub = jax.random.split(key)
            fan = k * k * c
            wgt = jax.random.normal(sub, (k, k, c, co)) * np.sqrt(2.0 / fan)
            # binary-weight layers carry no bias: the following BN's beta
            # absorbs it, and the +-1 lowering admits none
            params.append({"w": wgt} if l.get("wbin")
                          else {"w": wgt, "b": jnp.zeros((co,))})
            if l["pad"] == "VALID":
                h, w = (h - k) // l["stride"] + 1, (w - k) // l["stride"] + 1
            else:
                h, w = -(-h // l["stride"]), -(-w // l["stride"])
            c = co
        elif t == "dwconv":
            k = l["k"]
            key, sub = jax.random.split(key)
            wgt = jax.random.normal(sub, (k, k, 1, c)) * np.sqrt(2.0 / (k * k))
            params.append({"w": wgt})
            if l["pad"] == "VALID":
                h, w = (h - k) // l["stride"] + 1, (w - k) // l["stride"] + 1
            else:
                h, w = -(-h // l["stride"]), -(-w // l["stride"])
        elif t == "fc":
            if feat is None:
                feat = h * w * c if h else c
            key, sub = jax.random.split(key)
            wgt = jax.random.normal(sub, (feat, l["out"])) * np.sqrt(2.0 / feat)
            params.append({"w": wgt} if l.get("wbin")
                          else {"w": wgt, "b": jnp.zeros((l["out"],))})
            feat = l["out"]
        elif t == "bn":
            dim = feat if feat is not None else c
            params.append({"gamma": jnp.ones((dim,)), "beta": jnp.zeros((dim,)),
                           "mu": jnp.zeros((dim,)), "var": jnp.ones((dim,))})
        elif t == "pool":
            h, w = (h - l["k"]) // l["stride"] + 1, (w - l["k"]) // l["stride"] + 1
            params.append({})
        elif t == "flatten":
            feat = h * w * c
            params.append({})
        elif t == "gap":
            feat = c
            params.append({})
        else:  # act, res markers
            params.append({})
    return layers, params


# --------------------------------------------------------------------------
# float forward (training path)
# --------------------------------------------------------------------------
def forward_float(layers, params, x, train=False, bn_momentum=0.9):
    """Returns (logits, new_params) -- new_params carries updated BN
    running stats when train=True."""
    new_params = []
    res_stack = []
    for l, p in zip(layers, params):
        t = l["type"]
        np_ = p
        if t == "conv":
            w_eff = sign_ste_w(p["w"]) if l.get("wbin") else p["w"]
            x = jax.lax.conv_general_dilated(
                x, w_eff, (l["stride"], l["stride"]), l["pad"],
                dimension_numbers=("NHWC", "HWIO", "NHWC"))
            if "b" in p:
                x = x + p["b"]
        elif t == "dwconv":
            cin = x.shape[-1]
            w_eff = sign_ste_w(p["w"]) if l.get("wbin") else p["w"]
            x = jax.lax.conv_general_dilated(
                x, w_eff, (l["stride"], l["stride"]), l["pad"],
                dimension_numbers=("NHWC", "HWIO", "NHWC"),
                feature_group_count=cin)
        elif t == "fc":
            w_eff = sign_ste_w(p["w"]) if l.get("wbin") else p["w"]
            x = x @ w_eff
            if "b" in p:
                x = x + p["b"]
        elif t == "bn":
            axes = tuple(range(x.ndim - 1))
            if train:
                mu = jnp.mean(x, axis=axes)
                var = jnp.var(x, axis=axes)
                np_ = dict(p)
                np_["mu"] = bn_momentum * p["mu"] + (1 - bn_momentum) * mu
                np_["var"] = bn_momentum * p["var"] + (1 - bn_momentum) * var
            else:
                mu, var = p["mu"], p["var"]
            x = p["gamma"] * (x - mu) * jax.lax.rsqrt(var + 1e-5) + p["beta"]
        elif t == "act":
            x = sign_ste(x) if l["fn"] == "sign" else jax.nn.relu(x)
        elif t == "pool":
            x = jax.lax.reduce_window(
                x, -jnp.inf, jax.lax.max,
                (1, l["k"], l["k"], 1), (1, l["stride"], l["stride"], 1),
                "VALID")
        elif t == "flatten":
            x = x.reshape(x.shape[0], -1)
        elif t == "gap":
            x = jnp.mean(x, axis=(1, 2))
        elif t == "res_begin":
            res_stack.append(x)
        elif t == "res_end":
            r = res_stack.pop()
            if r.shape != x.shape:  # projection shortcut via stride/pad
                r = r[:, ::x.shape[1] and r.shape[1] // x.shape[1] or 1,
                      ::r.shape[2] // x.shape[2] or 1, :]
                pad_c = x.shape[-1] - r.shape[-1]
                if pad_c > 0:
                    r = jnp.pad(r, ((0, 0), (0, 0), (0, 0), (0, pad_c)))
            x = x + r
        new_params.append(np_)
    return x, new_params


def param_count(params) -> int:
    return int(sum(np.prod(v.shape) for p in params for v in p.values()))


# --------------------------------------------------------------------------
# fixed-point (ring) forward -- the engine oracle
# --------------------------------------------------------------------------
def wrap32(x):
    """Wrap int64 ndarray into signed int32 two's-complement (Z_{2^32})."""
    x = np.asarray(x, dtype=np.int64) & MASK32
    return np.where(x >= 1 << 31, x - (1 << 32), x).astype(np.int64)


def _im2col_chw(x, k, stride, pad_lo, pad_hi):
    """(C,H,W) int64 -> (k*k*C, OH*OW); K index = ((ky*k)+kx)*C + c."""
    c, h, w = x.shape
    xp = np.zeros((c, h + pad_lo + pad_hi, w + pad_lo + pad_hi), np.int64)
    xp[:, pad_lo:pad_lo + h, pad_lo:pad_lo + w] = x
    oh = (h + pad_lo + pad_hi - k) // stride + 1
    ow = (w + pad_lo + pad_hi - k) // stride + 1
    rows = np.empty((k * k * c, oh * ow), np.int64)
    for ky in range(k):
        for kx in range(k):
            patch = xp[:, ky:ky + oh * stride:stride, kx:kx + ow * stride:stride]
            rows[(ky * k + kx) * c:(ky * k + kx + 1) * c, :] = \
                patch.reshape(c, oh * ow)
    return rows, (oh, ow)


def forward_fixed(qlayers, x_fixed, stats=None):
    """Run the quantized/folded layer program on one sample.

    x_fixed: (C,H,W) int64 ring values (input image scaled by 2^s_in).
    qlayers: export.py layer program (dicts with int numpy payloads).
    stats: optional dict accumulating, per op index, the max |value| that
    feeds a secure comparison (sign input d, relu/trunc input z) -- used
    by export.calibrate to keep every MSB/trunc input inside the
    protocol's 2^bound_bits headroom.
    Returns int64 logits vector.  Every step wraps mod 2^32 -- bit-exact
    with the rust engine on reconstructed shares.
    """
    x = wrap32(x_fixed)          # (C,H,W) or (F,1) depending on stage
    shape_chw = x.ndim == 3
    for l in qlayers:
        op = l["op"]
        if op == "matmul":
            if shape_chw:
                cols, (oh, ow) = _im2col_chw(x, l["k"], l["stride"],
                                             l["pad_lo"], l["pad_hi"])
                z = wrap32(l["w"].astype(np.int64) @ cols)
                x = z.reshape(l["cout"], oh, ow)
            else:
                x = wrap32(l["w"].astype(np.int64) @ x)
            if l.get("b") is not None:
                x = wrap32(x + l["b"].astype(np.int64).reshape(-1, *([1] * (x.ndim - 1))))
        elif op == "depthwise":
            cols_per_c = []
            k = l["k"]
            for c in range(x.shape[0]):
                cols, (oh, ow) = _im2col_chw(x[c:c + 1], k, l["stride"],
                                             l["pad_lo"], l["pad_hi"])
                wrow = l["w"][c].astype(np.int64)  # (k*k,)
                cols_per_c.append(wrap32(wrow @ cols).reshape(oh, ow))
            x = np.stack(cols_per_c)
        elif op == "sign":
            t = l["t"].astype(np.int64).reshape(-1, *([1] * (x.ndim - 1)))
            s = l["flip"].astype(np.int64).reshape(-1, *([1] * (x.ndim - 1)))
            d = x - t          # true integer magnitude (pre-wrap)
            if stats is not None:
                idx = id(l)
                stats[idx] = max(stats.get(idx, 0), int(np.abs(d).max()))
            x = (wrap32(d * s) >= 0).astype(np.int64)
            # bits -> {-1,+1} happens lazily in the next linear via pm1
        elif op == "pm1":
            x = 2 * x - 1
        elif op == "relu":
            if stats is not None:
                idx = id(l)
                stats[idx] = max(stats.get(idx, 0), int(np.abs(x).max()))
            x = np.where(x >= 0, x, 0)
            if l.get("trunc"):
                x = x >> l["trunc"]
        elif op == "pool_bits":
            k, s = l["k"], l["stride"]
            c, h, w = x.shape
            oh, ow = (h - k) // s + 1, (w - k) // s + 1
            acc = np.zeros((c, oh, ow), np.int64)
            for i in range(k):
                for j in range(k):
                    acc += x[:, i:i + oh * s:s, j:j + ow * s:s]
            x = (acc - 1 >= 0).astype(np.int64)
        elif op == "flatten":
            x = x.reshape(-1, 1)    # CHW row-major -> column vector
            shape_chw = False
        else:
            raise ValueError(f"unknown op {op}")
    return x.reshape(-1)


def predict_fixed(qlayers, xs_fixed):
    """argmax over forward_fixed for a batch of (C,H,W) inputs."""
    return np.array([int(np.argmax(forward_fixed(qlayers, x)))
                     for x in xs_fixed])
