"""Network architectures from the paper's Table 4.

Each architecture is a list of layer specs (plain dicts so they serialize
straight into the rust-side manifest).  Layer types:

  conv   {k, stride, pad, cout, sep}   sep=True -> MPC-friendly separable
                                       (depthwise k x k + pointwise 1x1)
  fc     {out}
  bn     {}                            batch norm (folded at export)
  act    {fn: 'sign' | 'relu'}
  pool   {k, stride}                   maxpool
  flatten{}

Widths for the CIFAR nets are scaled by `width` (default 0.5) relative to
the published FitNet/VGG configs so that KD training fits the 1-core budget;
layer *counts* match Table 4 exactly.  Teachers (MnistNet4, CifarNet7/8) use
ReLU and full-precision activations.
"""

from __future__ import annotations


def conv(cout, k=3, stride=1, pad="SAME", sep=False, wbin=False):
    """wbin=True -> weights binarized to {-1,+1} via STE (customized-BNN
    hidden layers); export emits exact +-1 planes with no bias, which is
    what lets the secure engine lower the layer to XNOR+popcount."""
    return {"type": "conv", "k": k, "stride": stride, "pad": pad,
            "cout": cout, "sep": sep, "wbin": wbin}


def fc(out, wbin=False):
    return {"type": "fc", "out": out, "wbin": wbin}


def bn():
    return {"type": "bn"}


def act(fn):
    return {"type": "act", "fn": fn}


def pool(k=2, stride=2):
    return {"type": "pool", "k": k, "stride": stride}


def flatten():
    return {"type": "flatten"}


def _blockify(chans, acts, sep=False, k=3, pools=()):
    """conv->bn->act chains with optional maxpool after given indices."""
    layers = []
    for i, (c, a) in enumerate(zip(chans, acts)):
        layers += [conv(c, k=k, sep=sep), bn(), act(a)]
        if i in pools:
            layers.append(pool())
    return layers


def mnistnet1():
    """3 FC (XONN BM1-style: 784-128-128-10)."""
    return [flatten(),
            fc(128), bn(), act("sign"),
            fc(128), bn(), act("sign"),
            fc(10)]


def mnistnet2():
    """1 CONV + 2 FC (XONN BM2-style).  The conv uses ReLU so the secure
    engine exercises the ReLU + truncation path."""
    return [conv(16, k=5, stride=2, pad="VALID"), bn(), act("relu"),
            flatten(),
            fc(100), bn(), act("sign"),
            fc(10)]


def mnistnet3():
    """2 CONV, 2 MP, 2 FC (LeNet-style)."""
    return [conv(16, k=5, pad="VALID"), bn(), act("sign"), pool(),
            conv(16, k=5, pad="VALID"), bn(), act("sign"), pool(),
            flatten(),
            fc(100), bn(), act("sign"),
            fc(10)]


def mnistnet4():
    """Teacher for the MnistNets: same topology as MnistNet3, wider,
    full-precision ReLU activations."""
    return [conv(32, k=5, pad="VALID"), bn(), act("relu"), pool(),
            conv(32, k=5, pad="VALID"), bn(), act("relu"), pool(),
            flatten(),
            fc(256), bn(), act("relu"),
            fc(10)]


def lenet5():
    """Canonical zoo target: LeNet5-on-MNIST, customized per the paper --
    hidden layers use depthwise-separable convolutions and +-1 (wbin)
    weights with sign activations, so every hidden layer lowers to the
    engine's binary domain; the first conv and the logits fc stay
    fixed-point (the standard BNN first/last-layer exception)."""
    return [conv(6, k=5, pad="VALID"), bn(), act("sign"), pool(),
            conv(16, k=5, pad="VALID", sep=True, wbin=True), bn(),
            act("sign"), pool(),
            flatten(),
            fc(120, wbin=True), bn(), act("sign"),
            fc(84, wbin=True), bn(), act("sign"),
            fc(10)]


def vgg7(width=0.5):
    """Canonical zoo target: VGG7-on-CIFAR10 (6 conv + 1 fc), customized:
    separable +-1 hidden convolutions, sign activations, VALID padding
    throughout (the binary lowering admits no zero padding -- a padded 0
    is not a +-1 value).  Width scales channel counts like the other
    cifar nets."""
    w = lambda c: _w(width, c)
    return [conv(w(64), k=3, pad="VALID"), bn(), act("sign"),
            conv(w(64), k=3, pad="VALID", sep=True, wbin=True), bn(),
            act("sign"), pool(),
            conv(w(128), k=3, pad="VALID", sep=True, wbin=True), bn(),
            act("sign"),
            conv(w(128), k=3, pad="VALID", sep=True, wbin=True), bn(),
            act("sign"), pool(),
            conv(w(256), k=3, pad="VALID", sep=True, wbin=True), bn(),
            act("sign"),
            conv(w(256), k=3, pad="VALID", sep=True, wbin=True), bn(),
            act("sign"),
            flatten(), fc(10)]


def _w(width, c):
    return max(8, int(round(c * width)))


def cifarnet1(width=0.5, sep=True):
    """Binary MiniONN architecture: 7 CONV, 2 MP, 1 FC."""
    w = lambda c: _w(width, c)
    layers = _blockify([w(64), w(64)], ["sign"] * 2, sep=sep, pools=(1,))
    layers += _blockify([w(64), w(64)], ["sign"] * 2, sep=sep, pools=(1,))
    layers += _blockify([w(64)], ["sign"], sep=sep)
    layers += [conv(w(64), k=1), bn(), act("sign"),
               conv(16, k=1), bn(), act("sign"),
               flatten(), fc(10)]
    return layers


def cifarnet2(width=0.5, sep=True):
    """FitNet-1 binary variant: 9 CONV, 3 MP, 1 FC (13 layers)."""
    w = lambda c: _w(width, c)
    layers = _blockify([w(16), w(16), w(16)], ["sign"] * 3, sep=sep, pools=(2,))
    layers += _blockify([w(32), w(32), w(32)], ["sign"] * 3, sep=sep, pools=(2,))
    layers += _blockify([w(48), w(48), w(64)], ["sign"] * 3, sep=sep, pools=(2,))
    layers += [flatten(), fc(10)]
    return layers


def cifarnet2_typical(width=0.5):
    """Same topology as cifarnet2 but with standard (non-separable)
    convolutions -- the 'Typical BNN' row of Table 2."""
    return cifarnet2(width=width, sep=False)


def cifarnet3(width=0.5, sep=True):
    """FitNet-2 binary variant: 9 CONV, 3 MP, 1 FC; wider than cifarnet2."""
    w = lambda c: _w(width, c)
    layers = _blockify([w(16), w(32), w(32)], ["sign"] * 3, sep=sep, pools=(2,))
    layers += _blockify([w(48), w(64), w(80)], ["sign"] * 3, sep=sep, pools=(2,))
    layers += _blockify([w(96), w(96), w(128)], ["sign"] * 3, sep=sep, pools=(2,))
    layers += [flatten(), fc(10)]
    return layers


def cifarnet4(width=0.5, sep=True):
    """FitNet-3 binary variant: 11 CONV, 3 MP, 1 FC."""
    w = lambda c: _w(width, c)
    layers = _blockify([w(32), w(48), w(64), w(64)], ["sign"] * 4, sep=sep,
                       pools=(3,))
    layers += _blockify([w(80), w(80), w(80)], ["sign"] * 3, sep=sep,
                        pools=(2,))
    layers += _blockify([w(128), w(128), w(128), w(128)], ["sign"] * 4,
                        sep=sep, pools=(3,))
    layers += [flatten(), fc(10)]
    return layers


def cifarnet5(width=0.5, sep=True):
    """FitNet-4 binary variant: 17 CONV, 3 MP, 1 FC."""
    w = lambda c: _w(width, c)
    layers = _blockify([w(32)] * 5 + [w(48)], ["sign"] * 6, sep=sep, pools=(5,))
    layers += _blockify([w(80)] * 6, ["sign"] * 6, sep=sep, pools=(5,))
    layers += _blockify([w(128)] * 5, ["sign"] * 5, sep=sep, pools=(4,))
    layers += [flatten(), fc(10)]
    return layers


def cifarnet6(width=0.5, sep=True):
    """VGG16 binary variant: 13 CONV, 5 MP, 3 FC."""
    w = lambda c: _w(width, c)
    cfg = [(2, 64), (2, 128), (3, 256), (3, 512), (3, 512)]
    layers = []
    for reps, c in cfg:
        layers += _blockify([w(c)] * reps, ["sign"] * reps, sep=sep,
                            pools=(reps - 1,))
    layers += [flatten(),
               fc(_w(width, 512)), bn(), act("sign"),
               fc(_w(width, 512)), bn(), act("sign"),
               fc(10)]
    return layers


def cifarnet7(width=0.5):
    """Teacher: VGG16-style full-precision (ReLU, standard convs)."""
    w = lambda c: _w(width, c)
    cfg = [(2, 64), (2, 128), (3, 256), (3, 512), (3, 512)]
    layers = []
    for reps, c in cfg:
        layers += _blockify([w(c)] * reps, ["relu"] * reps, pools=(reps - 1,))
    layers += [flatten(),
               fc(w(512)), bn(), act("relu"),
               fc(w(512)), bn(), act("relu"),
               fc(10)]
    return layers


def cifarnet8(width=0.25):
    """Teacher: ResNet18-style.  Residual adds are expressed as explicit
    'res' markers; only used as a float teacher (never securely
    evaluated), so the secure layer IR does not need skip support."""
    w = lambda c: _w(width, c)
    layers = [conv(w(64)), bn(), act("relu")]
    for c, reps in [(64, 2), (128, 2), (256, 2), (512, 2)]:
        for r in range(reps):
            stride = 2 if (r == 0 and c != 64) else 1
            layers += [{"type": "res_begin"},
                       conv(w(c), stride=stride), bn(), act("relu"),
                       conv(w(c)), bn(),
                       {"type": "res_end"}, act("relu")]
    layers += [{"type": "gap"}, fc(10)]
    return layers


REGISTRY = {
    "lenet5": (lenet5, "mnist"),
    "vgg7": (vgg7, "cifar"),
    "mnistnet1": (mnistnet1, "mnist"),
    "mnistnet2": (mnistnet2, "mnist"),
    "mnistnet3": (mnistnet3, "mnist"),
    "mnistnet4": (mnistnet4, "mnist"),
    "cifarnet1": (cifarnet1, "cifar"),
    "cifarnet2": (cifarnet2, "cifar"),
    "cifarnet2_typical": (cifarnet2_typical, "cifar"),
    "cifarnet3": (cifarnet3, "cifar"),
    "cifarnet4": (cifarnet4, "cifar"),
    "cifarnet5": (cifarnet5, "cifar"),
    "cifarnet6": (cifarnet6, "cifar"),
    "cifarnet7": (cifarnet7, "cifar"),
    "cifarnet8": (cifarnet8, "cifar"),
}

INPUT_SHAPES = {"mnist": (28, 28, 1), "cifar": (32, 32, 3)}


def build(name: str, **kw):
    """Return (layers, input_shape) for a registered architecture."""
    fn, ds = REGISTRY[name]
    return fn(**kw), INPUT_SHAPES[ds]
