"""Model-zoo driver: train the canonical targets (LeNet5 / VGG7) with KD,
export versioned manifests + golden fixtures, and verify the fixed-point
accuracy floor before anything is committed.

    python -m compile.zoo                 # both models, full budget
    python -m compile.zoo --model lenet5  # one model
    python -m compile.zoo --quick         # smoke-test budget (no floor)

Artifacts land in fixtures/zoo/:

    <name>.manifest.json   versioned weight manifest (layer graph,
                           +-1 planes, folded sign thresholds)
    <name>.weights.bin     int32 LE weight pool
    <name>.golden.json     per-sample reference logits + labels +
                           fixed-point accuracy + committed floor
    mnist_subset.bin / cifar_subset.bin
                           eval subsets (export.export_eval_data format)

The golden logits are produced by `model.forward_fixed`, the bit-exact
python oracle of the rust engine; `rust/tests/zoo.rs` replays the same
subset through the secure walks and demands exact agreement.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

import numpy as np

from . import datasets, export, kd, networks
from . import model as M
from .train import ART, _save_params, _teacher, _train_one, load_params

FIXTURES = os.path.join(os.path.dirname(__file__), "..", "..",
                        "fixtures", "zoo")

# name -> (dataset, teacher net, accuracy floor, committed subset size)
ZOO = {
    "lenet5": ("mnist", "mnistnet4", 0.98, 256),
    "vgg7": ("cifar", "cifarnet7", 0.84, 128),
}


def _student(name, data, *, teacher, epochs, lr, seed, log, reuse):
    cache = os.path.join(ART, "models", f"{name}.npz")
    if reuse and os.path.exists(cache):
        log(f"[zoo] reusing cached {name}")
        return load_params(cache)
    layers, params, hist, _ = _train_one(
        name, data, teacher=teacher, lam=0.1, epochs=epochs, lr=lr,
        seed=seed, log=log)
    os.makedirs(os.path.dirname(cache), exist_ok=True)
    _save_params(cache, layers, params)
    log(f"[zoo] {name} float val_acc={hist['val_acc'][-1]:.4f}")
    return layers, params


def export_model(name, layers, params, data, out_dir, *, floor, subset,
                 check_floor=True, log=print):
    """quantize -> permute -> calibrate -> serialize -> golden fixtures.

    Returns the fixed-point accuracy on the exported subset.  Raises
    SystemExit if `check_floor` and the accuracy misses the floor --
    fixtures below the floor must never be committed.
    """
    ds = ZOO[name][0]
    in_shape = networks.INPUT_SHAPES[ds]
    _, _, xte, yte = data
    q = export.quantize(layers, [
        {k: np.asarray(v) for k, v in p.items()} for p in params], in_shape)
    q = export.permute_fc_after_flatten(q)
    calib = [export.fixed_input(xte[i]) for i in range(min(32, len(xte)))]
    export.calibrate(q, calib, log=log)
    os.makedirs(out_dir, exist_ok=True)
    export.serialize(name, ds, in_shape, q, out_dir)

    sub_path = os.path.join(out_dir, f"{ds}_subset.bin")
    export.export_eval_data(xte, yte, sub_path, n=subset)

    # round-trip through the serialized artifacts so the golden logits
    # certify the manifest itself, not the in-memory program
    _, q2 = export.load_manifest(
        os.path.join(out_dir, f"{name}.manifest.json"))
    imgs, labels = export.load_eval_data(sub_path)
    logits = np.stack([M.forward_fixed(q2, img) for img in imgs])
    acc = float((logits.argmax(axis=1) == labels).mean())
    log(f"[zoo] {name} fixed-point subset acc={acc:.4f} (floor {floor})")

    golden = {
        "name": name, "dataset": ds, "subset": os.path.basename(sub_path),
        "floor": floor, "accuracy": acc, "n": int(len(labels)),
        "labels": [int(v) for v in labels],
        "logits": [[int(v) for v in row] for row in logits],
    }
    with open(os.path.join(out_dir, f"{name}.golden.json"), "w") as f:
        json.dump(golden, f, indent=1)
    if check_floor and acc < floor:
        raise SystemExit(
            f"[zoo] {name}: fixed-point accuracy {acc:.4f} is below the "
            f"committed floor {floor}; fixtures not fit to commit")
    return acc


def run(names, *, quick=False, reuse=True, out_dir=FIXTURES, seed=0,
        log=print):
    os.makedirs(os.path.join(ART, "models"), exist_ok=True)
    accs = {}
    teachers = {}
    for name in names:
        ds, tname, floor, subset = ZOO[name]
        nm, nc = (800, 300) if quick else (6000, 1200)
        ep_t, ep_s = (2, 2) if quick else (8, 14)
        data = datasets.load(ds, nm, nc, seed=seed)
        if tname not in teachers:
            teachers[tname] = _teacher(tname, data, ep_t, log=log)
        layers, params = _student(
            name, data, teacher=teachers[tname], epochs=ep_s,
            lr=2e-3, seed=seed, log=log, reuse=reuse)
        accs[name] = export_model(
            name, layers, params, data, out_dir, floor=floor,
            subset=subset, check_floor=not quick, log=log)
    return accs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", choices=sorted(ZOO), action="append",
                    help="restrict to one model (repeatable)")
    ap.add_argument("--quick", action="store_true",
                    help="tiny budget; skips the accuracy-floor gate")
    ap.add_argument("--retrain", action="store_true",
                    help="ignore cached student weights")
    ap.add_argument("--out", default=FIXTURES)
    args = ap.parse_args()
    names = args.model or sorted(ZOO)
    accs = run(names, quick=args.quick, reuse=not args.retrain,
               out_dir=args.out)
    print(json.dumps(accs, indent=1))
    return 0


if __name__ == "__main__":
    sys.exit(main())
