"""Knowledge distillation (Hinton et al.) + a hand-rolled Adam.

optax is not available in this image, so Adam is implemented directly
(~20 lines).  The KD loss follows the paper's Eq. 1-5:

    L(x, y) = lambda * H_stu(y, softmax(z_s))
            + (1 - lambda) * T^2 * H_tea(softmax(z_t / T), softmax(z_s / T))

(the customary T^2 factor keeps gradient magnitudes comparable across
temperatures; with the paper's fixed T it only rescales the teacher term).
"""

from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from . import model as M


# --------------------------------------------------------------------------
# losses
# --------------------------------------------------------------------------
def cross_entropy(logits, labels):
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


def kd_loss(student_logits, teacher_logits, labels, lam, temperature):
    hard = cross_entropy(student_logits, labels)
    pt = jax.nn.softmax(teacher_logits / temperature)
    logq = jax.nn.log_softmax(student_logits / temperature)
    soft = -jnp.mean(jnp.sum(pt * logq, axis=1))
    return lam * hard + (1.0 - lam) * (temperature ** 2) * soft


# --------------------------------------------------------------------------
# Adam
# --------------------------------------------------------------------------
def adam_init(params):
    z = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": z, "v": jax.tree_util.tree_map(jnp.zeros_like, params),
            "t": 0}


def adam_step(params, grads, state, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g,
                               state["m"], grads)
    v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g,
                               state["v"], grads)
    mh = jax.tree_util.tree_map(lambda m: m / (1 - b1 ** t), m)
    vh = jax.tree_util.tree_map(lambda v: v / (1 - b2 ** t), v)
    new = jax.tree_util.tree_map(
        lambda p, mh, vh: p - lr * mh / (jnp.sqrt(vh) + eps), params, mh, vh)
    return new, {"m": m, "v": v, "t": t}


# --------------------------------------------------------------------------
# training loops
# --------------------------------------------------------------------------
_TRAINABLE = ("w", "b", "gamma", "beta")


def _split(params):
    """Separate trainable leaves from BN running stats."""
    train = [{k: v for k, v in p.items() if k in _TRAINABLE} for p in params]
    stats = [{k: v for k, v in p.items() if k not in _TRAINABLE} for p in params]
    return train, stats


def _merge(train, stats):
    return [{**t, **s} for t, s in zip(train, stats)]


def evaluate(layers, params, x, y, batch=256):
    correct = 0
    for i in range(0, len(x), batch):
        logits, _ = M.forward_float(layers, params, jnp.asarray(x[i:i + batch]))
        correct += int(jnp.sum(jnp.argmax(logits, 1) == jnp.asarray(y[i:i + batch])))
    return correct / len(x)


def train(layers, params, data, *, epochs=5, batch=64, lr=1e-3,
          teacher=None, lam=1.0, temperature=10.0, seed=0, log=None):
    """Train (optionally with KD).  teacher = (t_layers, t_params) or None.
    Returns (params, history) where history records per-epoch val accuracy
    and cumulative wall-clock seconds (Fig 5b / Fig 6b data)."""
    xtr, ytr, xte, yte = data
    rng = np.random.default_rng(seed)
    tparams, stats = _split(params)
    opt = adam_init(tparams)

    t_logits_fn = None
    if teacher is not None:
        t_layers, t_params = teacher

        @jax.jit
        def t_logits_fn(xb):
            lg, _ = M.forward_float(t_layers, t_params, xb)
            return lg

    @jax.jit
    def step(tparams, stats, opt, xb, yb, t_logits):
        def loss_fn(tp):
            full = _merge(tp, stats)
            logits, new_full = M.forward_float(layers, full, xb, train=True)
            if teacher is None:
                l = cross_entropy(logits, yb)
            else:
                l = kd_loss(logits, t_logits, yb, lam, temperature)
            new_stats = [{k: v for k, v in p.items() if k not in _TRAINABLE}
                         for p in new_full]
            return l, new_stats
        (l, new_stats), grads = jax.value_and_grad(loss_fn, has_aux=True)(tparams)
        tparams, opt = adam_step(tparams, grads, opt, lr=lr)
        return tparams, new_stats, opt, l

    history = {"epoch": [], "val_acc": [], "loss": [], "wall_s": []}
    t0 = time.perf_counter()
    n = len(xtr)
    for ep in range(epochs):
        order = rng.permutation(n)
        losses = []
        for i in range(0, n - batch + 1, batch):
            idx = order[i:i + batch]
            xb, yb = jnp.asarray(xtr[idx]), jnp.asarray(ytr[idx])
            tl = t_logits_fn(xb) if t_logits_fn else jnp.zeros((len(idx), 10))
            tparams, stats, opt, l = step(tparams, stats, opt, xb, yb, tl)
            losses.append(float(l))
        acc = evaluate(layers, _merge(tparams, stats), xte, yte)
        history["epoch"].append(ep + 1)
        history["val_acc"].append(acc)
        history["loss"].append(float(np.mean(losses)))
        history["wall_s"].append(time.perf_counter() - t0)
        if log:
            log(f"  epoch {ep + 1}/{epochs} loss={np.mean(losses):.4f} "
                f"val_acc={acc:.4f}")
    return _merge(tparams, stats), history
