"""L1 Pallas kernels for the binarization path.

`sign_bits` implements the paper's Sign activation semantics (bit = 1 ^
MSB(x - t), i.e. 1 iff x >= t) with the per-channel BN-fused threshold and
orientation flip (Section 3.5, Eq. 8).  `pool_or_bits` is the Sign-fused
maxpooling of Section 3.6 (window OR as sign(sum - 1)).

These run elementwise / reduction-wise, so the TPU mapping is a simple 1-D
block grid over the flattened tensor; on CPU they execute under
interpret=True and lower into the same HLO as the model graph.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sign_kernel(z_ref, t_ref, s_ref, o_ref):
    d = (z_ref[...] - t_ref[...]) * s_ref[...]
    o_ref[...] = (d >= 0).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def sign_bits(z, t, flip_sign, block=4096, interpret=True):
    """bit = 1{ (z - t) * flip >= 0 } over int32 tensors.

    z: (C, N) channel-major activations; t: (C, 1) thresholds;
    flip_sign: (C, 1) in {+1, -1} (-1 when the folded BN gamma' < 0).
    """
    c, n = z.shape
    bn = min(block, max(8, n))
    pad = (-n) % bn
    if pad:
        z = jnp.pad(z, ((0, 0), (0, pad)))
    tb = jnp.broadcast_to(t, z.shape)
    sb = jnp.broadcast_to(flip_sign, z.shape)
    out = pl.pallas_call(
        _sign_kernel,
        grid=(z.shape[1] // bn,),
        in_specs=[pl.BlockSpec((c, bn), lambda j: (0, j))] * 3,
        out_specs=pl.BlockSpec((c, bn), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct(z.shape, jnp.int32),
        interpret=interpret,
    )(z, tb, sb)
    return out[:, :n]


def pool_or_bits(bits_chw, k=2, stride=2, interpret=True):
    """Sign-fused maxpool over {0,1} bit tensors in (C,H,W) layout:
    out = 1{ sum(window) - 1 >= 0 }."""
    c, h, w = bits_chw.shape
    oh, ow = (h - k) // stride + 1, (w - k) // stride + 1
    s = jnp.zeros((c, oh, ow), jnp.int32)
    for i in range(k):
        for j in range(k):
            s = s + bits_chw[:, i:i + oh * stride:stride,
                             j:j + ow * stride:stride]
    flat = s.reshape(c, oh * ow)
    one = jnp.ones((c, 1), jnp.int32)
    return sign_bits(flat, one, one, interpret=interpret).reshape(c, oh, ow)
