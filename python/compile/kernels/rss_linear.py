"""L1 Pallas kernel: the fused 3-term RSS linear-layer local computation.

This is the compute hot-spot of Algorithm 2: each party locally evaluates

    Z_i = W_i X_i + W_{i+1} X_i + W_i X_{i+1}          (mod 2^32)

for its two replicated shares.  The kernel fuses the three products into a
single pass over the tiles, exploiting the ring identity

    W_i X_i + W_{i+1} X_i + W_i X_{i+1} = (W_i + W_{i+1}) X_i + W_i X_{i+1}

so only TWO MXU contractions per tile are issued instead of three, and
X_i / X_{i+1} tiles make exactly one HBM->VMEM round-trip.

TPU mapping (DESIGN.md "Hardware adaptation"): grid (M/bm, N/bn, K/bk) with
the K dimension innermost ("arbitrary" semantics -> sequential), output
block revisited across K steps and accumulated in place in VMEM.  On this
CPU image the kernel runs under interpret=True; the identical jaxpr lowers
to the HLO that the rust PJRT runtime executes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dot(a, b):
    return jax.lax.dot_general(a, b, (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.int32)


def _rss_mm_kernel(wi_ref, wi1_ref, xi_ref, xi1_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    wi = wi_ref[...]
    # two contractions instead of three (ring identity above)
    o_ref[...] += _dot(wi + wi1_ref[...], xi_ref[...]) + _dot(wi, xi1_ref[...])


def _pad_to(a, m0, m1):
    p0 = (-a.shape[0]) % m0
    p1 = (-a.shape[1]) % m1
    if p0 or p1:
        a = jnp.pad(a, ((0, p0), (0, p1)))
    return a


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn", "interpret"))
def rss_matmul(wi, wi1, xi, xi1, bm=128, bk=128, bn=128, interpret=True):
    """Fused Z_i = W_i X_i + W_{i+1} X_i + W_i X_{i+1} over int32.

    Shapes: w* (M,K), x* (K,N) -> (M,N).  Inputs are zero-padded up to the
    block grid and the result sliced back, so arbitrary shapes are fine.
    """
    m, k = wi.shape
    _, n = xi.shape
    bm, bk, bn = min(bm, _rup(m)), min(bk, _rup(k)), min(bn, _rup(n))
    wi_p, wi1_p = _pad_to(wi, bm, bk), _pad_to(wi1, bm, bk)
    xi_p, xi1_p = _pad_to(xi, bk, bn), _pad_to(xi1, bk, bn)
    mp, kp = wi_p.shape
    _, np_ = xi_p.shape
    grid = (mp // bm, np_ // bn, kp // bk)
    out = pl.pallas_call(
        _rss_mm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, s: (i, s)),
            pl.BlockSpec((bm, bk), lambda i, j, s: (i, s)),
            pl.BlockSpec((bk, bn), lambda i, j, s: (s, j)),
            pl.BlockSpec((bk, bn), lambda i, j, s: (s, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.int32),
        interpret=interpret,
    )(wi_p, wi1_p, xi_p, xi1_p)
    return out[:m, :n]


def _rup(x, m=8):
    """Round up to a sane minimum block granularity."""
    return max(m, x)


def rss_matmul_bias(wi, wi1, xi, xi1, bi, **kw):
    """rss_matmul plus the party's additive bias share (column broadcast)."""
    return rss_matmul(wi, wi1, xi, xi1, **kw) + bi


def vmem_footprint_bytes(bm, bk, bn):
    """Estimated VMEM residency of one grid step (int32 = 4 bytes):
    two W tiles + two X tiles + one accumulator tile."""
    return 4 * (2 * bm * bk + 2 * bk * bn + bm * bn)


def mxu_utilization_estimate(m, k, n, bm=128, bk=128, bn=128):
    """Fraction of MXU-issued MACs that are useful (non-padding), i.e.
    true_flops / padded_flops for the chosen blocking.  Used for the
    DESIGN.md real-TPU efficiency estimate."""
    ceil = lambda a, b: -(-a // b) * b
    padded = ceil(m, bm) * ceil(k, bk) * ceil(n, bn)
    return (m * k * n) / padded
