"""Pure-jnp correctness oracles for the Pallas kernels.

Everything here is the *definition* of correct behaviour; the Pallas
kernels in rss_linear.py / binary.py are checked against these in
python/tests/test_kernels.py (hypothesis sweeps) and indirectly by the
rust engine's golden tests.

All ring arithmetic is int32 with wrap-around (two's complement), which is
exactly Z_{2^32}.
"""

from __future__ import annotations

import jax.numpy as jnp
import jax


def rss_matmul_ref(wi, wi1, xi, xi1):
    """Local RSS linear-layer term (Algorithm 2, step 2):

        Z_i = W_i X_i + W_{i+1} X_i + W_i X_{i+1}   (mod 2^32)
    """
    dot = lambda a, b: jax.lax.dot_general(
        a, b, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)
    return dot(wi, xi) + dot(wi1, xi) + dot(wi, xi1)


def rss_conv_ref(wi, wi1, xi, xi1, stride=1, pad="SAME"):
    """Same three-term contraction for NHWC x HWIO convolution."""
    cv = lambda x, k: jax.lax.conv_general_dilated(
        x, k, (stride, stride), pad,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        preferred_element_type=jnp.int32)
    return cv(xi, wi) + cv(xi, wi1) + cv(xi1, wi)


def rss_depthwise_ref(wi, wi1, xi, xi1, stride=1, pad="SAME"):
    """Three-term depthwise convolution; w has shape (H,W,1,C)."""
    c = xi.shape[-1]
    cv = lambda x, k: jax.lax.conv_general_dilated(
        x, k, (stride, stride), pad,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=c,
        preferred_element_type=jnp.int32)
    return cv(xi, wi) + cv(xi, wi1) + cv(xi1, wi)


def im2col_ref(x, k, stride, pad_lo, pad_hi):
    """NHWC -> (N*OH*OW, K*K*C) patch matrix, int32, zero padding."""
    n, h, w, c = x.shape
    x = jnp.pad(x, ((0, 0), (pad_lo, pad_hi), (pad_lo, pad_hi), (0, 0)))
    oh = (h + pad_lo + pad_hi - k) // stride + 1
    ow = (w + pad_lo + pad_hi - k) // stride + 1
    cols = []
    for i in range(k):
        for j in range(k):
            cols.append(x[:, i:i + oh * stride:stride,
                          j:j + ow * stride:stride, :])
    # (N, OH, OW, K*K, C) -> (N*OH*OW, K*K*C)
    patches = jnp.stack(cols, axis=3)
    return patches.reshape(n * oh * ow, k * k * c), (oh, ow)


def sign_bits_ref(x):
    """Plaintext Sign activation as the paper defines it:
    1 ^ MSB(x) -> bit in {0,1}; 1 iff x >= 0 (two's complement)."""
    return (x >= 0).astype(jnp.int32)


def sign_pm1_ref(x):
    """Sign activation mapped to {-1,+1} = 2*bit - 1."""
    return 2 * sign_bits_ref(x) - 1


def maxpool_or_ref(bits, k=2, stride=2):
    """Sign-fused maxpool (paper 3.6): OR over the window of {0,1} bits,
    computed as sign(sum - 1) over NHWC int32 bit tensors."""
    n, h, w, c = bits.shape
    oh, ow = (h - k) // stride + 1, (w - k) // stride + 1
    s = jnp.zeros((n, oh, ow, c), jnp.int32)
    for i in range(k):
        for j in range(k):
            s = s + bits[:, i:i + oh * stride:stride,
                         j:j + ow * stride:stride, :]
    return sign_bits_ref(s - 1)


def trunc_ref(x, f):
    """Arithmetic-shift truncation by f fractional bits (signed)."""
    return jnp.right_shift(x, f)
