"""Training driver: produces model weights + the Fig 5 / Fig 6 experiment
JSONs.

    python -m compile.train --exp weights   # train + save nets for aot.py
    python -m compile.train --exp fig5      # MNIST: OriNets vs customized
    python -m compile.train --exp fig6      # CIFAR: lambda sweep + curves
    python -m compile.train --exp all

Budget knobs (--quick) keep everything runnable on one CPU core in
minutes; dataset sizes / epochs are recorded in the JSON so EXPERIMENTS.md
can cite them.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np
import jax

from . import datasets, kd, networks
from . import model as M

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def _save_params(path, layers, params):
    flat = {}
    for i, p in enumerate(params):
        for k, v in p.items():
            flat[f"{i}:{k}"] = np.asarray(v)
    np.savez(path, layers=json.dumps(layers), **flat)


def load_params(path):
    z = np.load(path, allow_pickle=False)
    layers = json.loads(str(z["layers"]))
    params = [{} for _ in layers]
    for key in z.files:
        if key == "layers":
            continue
        i, k = key.split(":")
        params[int(i)][k] = jax.numpy.asarray(z[key])
    return layers, params


def _train_one(name, data, *, teacher=None, lam=0.1, temperature=10.0,
               epochs=6, lr=2e-3, seed=0, width_kw=None, log=print):
    layers0, in_shape = networks.build(name, **(width_kw or {}))
    layers, params = M.init_params(layers0, in_shape,
                                   jax.random.PRNGKey(seed))
    log(f"[train] {name}: {M.param_count(params)} params, "
        f"{'KD' if teacher else 'plain'}")
    params, hist = kd.train(layers, params, data, epochs=epochs, lr=lr,
                            teacher=teacher, lam=lam, temperature=temperature,
                            seed=seed, log=log)
    return layers, params, hist, in_shape


def _teacher(name, data, epochs, seed=0, log=print):
    cache = os.path.join(ART, "models", f"{name}.npz")
    if os.path.exists(cache):
        log(f"[teacher] cached {name}")
        return load_params(cache)
    layers, params, hist, _ = _train_one(name, data, epochs=epochs,
                                         seed=seed, log=log)
    os.makedirs(os.path.dirname(cache), exist_ok=True)
    _save_params(cache, layers, params)
    log(f"[teacher] {name} val_acc={hist['val_acc'][-1]:.4f}")
    return layers, params


def exp_weights(quick, log=print):
    """Train and save every securely-evaluated network."""
    nm, nc = (1500, 400) if quick else (4000, 800)
    ep_t, ep_s = (3, 4) if quick else (8, 10)
    out = {}
    mnist = datasets.load("mnist", nm, nc)
    teacher_m = _teacher("mnistnet4", mnist, ep_t, log=log)
    for name in ("mnistnet1", "mnistnet2", "mnistnet3"):
        layers, params, hist, _ = _train_one(
            name, mnist, teacher=teacher_m, lam=0.1, epochs=ep_s, log=log)
        _save_params(os.path.join(ART, "models", f"{name}.npz"),
                     layers, params)
        out[name] = hist["val_acc"][-1]
    cifar = datasets.load("cifar", nm, nc)
    teacher_c = _teacher("cifarnet7", cifar, ep_t, log=log)
    for name, kw in (("cifarnet2", {}), ("cifarnet2_typical", {})):
        layers, params, hist, _ = _train_one(
            name, cifar, teacher=teacher_c, lam=0.1, epochs=ep_s,
            width_kw=kw, log=log)
        _save_params(os.path.join(ART, "models", f"{name}.npz"),
                     layers, params)
        out[name] = hist["val_acc"][-1]
    with open(os.path.join(ART, "experiments", "plaintext_acc.json"), "w") as f:
        json.dump(out, f, indent=1)
    return out


def exp_fig5(quick, log=print):
    """Fig 5: customized (KD) vs typical (OriNet) training on MNIST."""
    nm, nc = (1500, 400) if quick else (4000, 800)
    eps = 4 if quick else 10
    data = datasets.load("mnist", nm, nc)
    teacher = _teacher("mnistnet4", data, 3 if quick else 8, log=log)
    res = {"meta": {"n_train": nm, "n_test": nc, "epochs": eps,
                    "lambda": 0.1, "T": 10.0,
                    "dataset": "synth-mnist (see DESIGN.md substitutions)"}}
    for name in ("mnistnet1", "mnistnet2", "mnistnet3"):
        _, _, h_kd, _ = _train_one(name, data, teacher=teacher, lam=0.1,
                                   epochs=eps, log=log)
        _, _, h_ori, _ = _train_one(name, data, teacher=None,
                                    epochs=eps, log=log)
        res[name] = {"customized": h_kd, "orinet": h_ori}
    os.makedirs(os.path.join(ART, "experiments"), exist_ok=True)
    with open(os.path.join(ART, "experiments", "fig5.json"), "w") as f:
        json.dump(res, f, indent=1)
    log("[fig5] written")
    return res


def exp_fig6(quick, log=print):
    """Fig 6(a): KD lambda sweep on CIFAR; Fig 6(b): convergence curves."""
    nm, nc = (1200, 300) if quick else (3000, 600)
    eps = 3 if quick else 8
    data = datasets.load("cifar", nm, nc)
    teacher = _teacher("cifarnet7", data, 3 if quick else 8, log=log)
    lams = [0.1, 0.3, 0.5, 0.7, 0.9, 1.0]
    sweep = {}
    for lam in lams:
        _, _, h, _ = _train_one("cifarnet2", data, teacher=teacher, lam=lam,
                                epochs=eps, log=log)
        sweep[str(lam)] = h["val_acc"][-1]
    _, _, h_cust, _ = _train_one("cifarnet2", data, teacher=teacher, lam=0.1,
                                 epochs=eps, log=log)
    _, _, h_typ, _ = _train_one("cifarnet2_typical", data, teacher=None,
                                epochs=eps, log=log)
    res = {"meta": {"n_train": nm, "n_test": nc, "epochs": eps, "T": 10.0,
                    "dataset": "synth-cifar (see DESIGN.md substitutions)"},
           "lambda_sweep": sweep,
           "curves": {"customized": h_cust, "typical": h_typ}}
    with open(os.path.join(ART, "experiments", "fig6.json"), "w") as f:
        json.dump(res, f, indent=1)
    log("[fig6] written")
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--exp", default="weights",
                    choices=["weights", "fig5", "fig6", "all"])
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    os.makedirs(os.path.join(ART, "models"), exist_ok=True)
    os.makedirs(os.path.join(ART, "experiments"), exist_ok=True)
    t0 = time.perf_counter()
    if args.exp in ("weights", "all"):
        exp_weights(args.quick)
    if args.exp in ("fig5", "all"):
        exp_fig5(args.quick)
    if args.exp in ("fig6", "all"):
        exp_fig6(args.quick)
    print(f"[train] done in {time.perf_counter() - t0:.1f}s")


if __name__ == "__main__":
    main()
