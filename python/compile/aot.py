"""AOT export: float checkpoints -> integer layer programs + per-layer HLO.

For every securely-evaluated network this emits, under artifacts/:

  models/<name>.manifest.json   layer program (ops, shapes, scales, HLO ids)
  models/<name>.weights.bin     int32 LE tensor pool (weights, biases,
                                thresholds, flips)
  hlo/<id>.pallas.hlo.txt       Algorithm-2 local RSS contraction, lowered
                                from the L1 Pallas kernel (interpret=True)
  hlo/<id>.xla.hlo.txt          same computation as plain jnp ops (ablation
                                arm A4 + runtime fallback)
  data/<dataset>.bin            fixed-point eval images + labels
  golden/<name>.golden.json     forward_fixed logits for the first samples
                                (rust integration tests assert bit-equality)

HLO text (never .serialize()) is the interchange format -- see
/opt/xla-example/README.md: jax>=0.5 emits 64-bit instruction ids that
xla_extension 0.5.1 rejects; the text parser reassigns ids.
"""

from __future__ import annotations

import argparse
import json
import os

import numpy as np
import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import datasets, export, networks, train
from . import model as M
from .kernels import ref, rss_linear

ART = train.ART

SECURE_NETS = ("mnistnet1", "mnistnet2", "mnistnet3",
               "cifarnet2", "cifarnet2_typical")


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


# --------------------------------------------------------------------------
# HLO builders
# --------------------------------------------------------------------------
def _mm_fn_pallas(wi, wi1, xi, xi1, bi):
    return (rss_linear.rss_matmul(wi, wi1, xi, xi1, interpret=True) + bi,)


def _mm_fn_xla(wi, wi1, xi, xi1, bi):
    return (ref.rss_matmul_ref(wi, wi1, xi, xi1) + bi,)


def lower_matmul(m, k, n, variant):
    s = lambda *sh: jax.ShapeDtypeStruct(sh, jnp.int32)
    fn = _mm_fn_pallas if variant == "pallas" else _mm_fn_xla
    lowered = jax.jit(fn).lower(s(m, k), s(m, k), s(k, n), s(k, n), s(m, 1))
    return to_hlo_text(lowered)


def lower_depthwise(c, h, w, k, stride, pad_lo, pad_hi, variant):
    """Depthwise three-term RSS conv in NCHW (batch=1).  The depthwise
    contraction is tiny (k^2 MACs/output); it is lowered directly from
    lax.conv (variant is accepted for a uniform interface)."""
    del variant
    s = lambda *sh: jax.ShapeDtypeStruct(sh, jnp.int32)

    def fn(wi, wi1, xi, xi1):
        cv = lambda x, kk: jax.lax.conv_general_dilated(
            x, kk, (stride, stride), [(pad_lo, pad_hi), (pad_lo, pad_hi)],
            dimension_numbers=("NCHW", "HWIO", "NCHW"),
            feature_group_count=c,
            preferred_element_type=jnp.int32)
        return (cv(xi, wi) + cv(xi, wi1) + cv(xi1, wi),)

    lowered = jax.jit(fn).lower(s(k, k, 1, c), s(k, k, 1, c),
                                s(1, c, h, w), s(1, c, h, w))
    return to_hlo_text(lowered)


# --------------------------------------------------------------------------
# export pipeline
# --------------------------------------------------------------------------
def export_network(name, hlo_dir, model_dir, golden_dir, eval_x, eval_y,
                   log=print, n_golden=8):
    layers, params = train.load_params(
        os.path.join(ART, "models", f"{name}.npz"))
    _, in_shape = networks.build(name)
    q = export.quantize(layers, params, in_shape)
    q = export.permute_fc_after_flatten(q)
    # keep every MSB/trunc input inside the protocol headroom
    calib = [export.fixed_input(eval_x[i]) for i in range(16)]
    q = export.calibrate(q, calib, log=log)

    # ---- unique HLO ids per linear layer -------------------------------
    hlo_names, emitted = [], set()
    h, w, c = in_shape
    cur = (in_shape[2], in_shape[0], in_shape[1])   # (C,H,W)
    for l in q:
        if l["op"] == "matmul":
            if l.get("conv"):
                kk, st = l["k"], l["stride"]
                oh = (cur[1] + l["pad_lo"] + l["pad_hi"] - kk) // st + 1
                ow = (cur[2] + l["pad_lo"] + l["pad_hi"] - kk) // st + 1
                mm = (l["m"], l["kdim"], oh * ow)
                cur = (l["cout"], oh, ow)
            else:
                mm = (l["m"], l["kdim"], 1)
            hid = f"rss_mm_{mm[0]}x{mm[1]}x{mm[2]}"
            hlo_names.append(hid)
            if hid not in emitted:
                emitted.add(hid)
                for var in ("pallas", "xla"):
                    txt = lower_matmul(*mm, var)
                    with open(os.path.join(hlo_dir, f"{hid}.{var}.hlo.txt"),
                              "w") as f:
                        f.write(txt)
            l["n"] = mm[2]
        elif l["op"] == "depthwise":
            cc, hh, ww = cur
            kk, st = l["k"], l["stride"]
            hid = (f"rss_dw_c{cc}h{hh}w{ww}k{kk}s{st}"
                   f"p{l['pad_lo']}_{l['pad_hi']}")
            hlo_names.append(hid)
            if hid not in emitted:
                emitted.add(hid)
                txt = lower_depthwise(cc, hh, ww, kk, st,
                                      l["pad_lo"], l["pad_hi"], "xla")
                for var in ("pallas", "xla"):
                    with open(os.path.join(hlo_dir, f"{hid}.{var}.hlo.txt"),
                              "w") as f:
                        f.write(txt)
            oh = (hh + l["pad_lo"] + l["pad_hi"] - kk) // st + 1
            ow = (ww + l["pad_lo"] + l["pad_hi"] - kk) // st + 1
            cur = (cc, oh, ow)
        elif l["op"] == "pool_bits":
            cur = (cur[0], (cur[1] - l["k"]) // l["stride"] + 1,
                   (cur[2] - l["k"]) // l["stride"] + 1)

    manifest = export.serialize(name, networks.REGISTRY[name][1], in_shape,
                                q, model_dir, hlo_names=hlo_names)

    # ---- golden outputs -------------------------------------------------
    logits, preds = [], []
    for i in range(n_golden):
        lg = M.forward_fixed(q, export.fixed_input(eval_x[i]))
        logits.append([int(v) for v in lg])
        preds.append(int(np.argmax(lg)))
    golden = {"name": name, "logits": logits, "preds": preds,
              "labels": [int(v) for v in eval_y[:n_golden]]}
    with open(os.path.join(golden_dir, f"{name}.golden.json"), "w") as f:
        json.dump(golden, f, indent=1)

    # secure-path accuracy on the eval slice (recorded for the tables)
    n_acc = min(len(eval_x), 128)
    pr = M.predict_fixed(
        q, [export.fixed_input(eval_x[i]) for i in range(n_acc)])
    acc = float(np.mean(pr == eval_y[:n_acc]))
    log(f"[aot] {name}: layers={len(manifest['layers'])} "
        f"fixed_acc={acc:.4f}")
    return {"fixed_acc": acc, "n_eval": n_acc,
            "params": M.param_count(params)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None, help="ignored; kept for Makefile")
    ap.add_argument("--nets", default=",".join(SECURE_NETS))
    ap.add_argument("--quick", action="store_true",
                    help="train missing checkpoints with the quick budget")
    args = ap.parse_args()

    hlo_dir = os.path.join(ART, "hlo")
    model_dir = os.path.join(ART, "models")
    golden_dir = os.path.join(ART, "golden")
    data_dir = os.path.join(ART, "data")
    for d in (hlo_dir, model_dir, golden_dir, data_dir,
              os.path.join(ART, "experiments")):
        os.makedirs(d, exist_ok=True)

    nets = [n for n in args.nets.split(",") if n]
    missing = [n for n in nets
               if not os.path.exists(os.path.join(model_dir, f"{n}.npz"))]
    if missing:
        print(f"[aot] training missing checkpoints: {missing}")
        train.exp_weights(quick=True)

    evals, meta = {}, {}
    for ds in ("mnist", "cifar"):
        _, _, xte, yte = datasets.load(ds, 8, 256)
        evals[ds] = (xte, yte)
        export.export_eval_data(xte, yte,
                                os.path.join(data_dir, f"{ds}.bin"), n=256)

    for name in nets:
        ds = networks.REGISTRY[name][1]
        meta[name] = export_network(name, hlo_dir, model_dir, golden_dir,
                                    *evals[ds])
    with open(os.path.join(ART, "experiments", "secure_acc.json"), "w") as f:
        json.dump(meta, f, indent=1)
    print("[aot] export complete")


if __name__ == "__main__":
    main()
